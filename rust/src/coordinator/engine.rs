//! [`IoEngine`] — the unified submission pipeline of the I/O stack:
//! **merge → batch → admit → poll-retire**, as one object.
//!
//! Before this module existed the policy pieces ([`merge_queue`],
//! [`batching`], [`regulator`], [`channel`], [`node`]) were assembled by
//! hand at every call site (sim engine, loopback client, each experiment
//! harness). `IoEngine` owns the whole pipeline:
//!
//! * **Sharded merge queues** — one read/write queue pair per QP
//!   (`qps_per_node` channels per remote node, paper §6.1). Submissions are
//!   routed to a shard by an address-affine hash over 1 MiB regions, so
//!   adjacent requests land in the same shard and Batching-on-MR still
//!   finds its merge candidates, while independent regions engage
//!   independent QPs (and therefore independent NIC processing units).
//! * **Batch planning** — each shard drain runs through the
//!   [`batching::plan`] planner (Single / BatchOnMr / Doorbell / Hybrid).
//! * **Admission control** — drains are bounded by the [`Regulator`]
//!   window; a closed window leaves requests queued where later arrivals
//!   keep merging with them (paper §5.1).
//! * **Replicated placement** — in placed mode the engine routes by
//!   [`NodeMap`]: writes fan out to every alive replica, reads go to the
//!   first alive replica and *fail over* to the next on completion error;
//!   an application I/O retires exactly once, and only when its
//!   replication policy is satisfied. All replicas dead surfaces the
//!   paper's disk-fallback signal instead of an I/O.
//!
//! The same object is driven by the discrete-event fabric
//! ([`crate::fabric::sim`], via `StackEngine`) and by the live loopback
//! fabric ([`crate::fabric::loopback`], via `LiveBox`): the backends only
//! move bytes and deliver completions; every policy decision is here.
//!
//! [`merge_queue`]: crate::coordinator::merge_queue
//! [`batching`]: crate::coordinator::batching
//! [`regulator`]: crate::coordinator::regulator
//! [`channel`]: crate::coordinator::channel
//! [`node`]: crate::coordinator::node

use crate::config::FabricConfig;
use crate::coordinator::batching::{plan, BatchLimits, BatchMode};
use crate::coordinator::channel::ChannelMap;
use crate::coordinator::merge_queue::{MergeCheck, MergeQueues};
use crate::coordinator::node::{NodeMap, ReadRoute};
use crate::coordinator::regulator::Regulator;
use crate::coordinator::StackConfig;
use crate::fabric::{AppIo, Dir, NodeId, QpId, Wc, WcStatus, WorkRequest};
use crate::util::fxhash::FxHashMap;

/// Shard affinity region size (re-exported from the channel layer, which
/// owns the routing function). Because merging only happens within one
/// shard's drain, a multi-SGE WR never spans a region boundary when
/// `qps_per_node > 1`.
pub use crate::coordinator::channel::SHARD_REGION_SHIFT;

/// CPU costs the engine charges on the (serialized) drain path. The sim
/// backend fills these from the calibrated fabric model; the live backend
/// runs with [`EngineCosts::free`] (real time is measured, not modeled).
#[derive(Debug, Clone, Copy, Default)]
pub struct EngineCosts {
    /// Per-WQE posting cost (verbs post_send + block layer).
    pub post_wqe_cpu_ns: u64,
    /// Per-chain MMIO doorbell cost.
    pub mmio_cpu_ns: u64,
    /// Fixed cost of one merge-check (lock + scan setup).
    pub merge_check_base_ns: u64,
    /// Per-request merge-scan cost.
    pub merge_check_per_io_ns: u64,
}

impl EngineCosts {
    pub fn from_fabric(cfg: &FabricConfig) -> Self {
        Self {
            post_wqe_cpu_ns: cfg.post_wqe_cpu_ns,
            mmio_cpu_ns: cfg.mmio_cpu_ns,
            merge_check_base_ns: 120,
            merge_check_per_io_ns: 25,
        }
    }

    /// Zero-cost model (live backends measure wall time instead).
    pub fn free() -> Self {
        Self::default()
    }
}

/// How submissions are routed to remote nodes.
#[derive(Debug)]
enum Routing {
    /// The caller names the destination node in `AppIo::node`.
    Direct,
    /// The engine places by address: replica fan-out, read failover, disk
    /// fallback (paper §6/§7.1).
    Placed(NodeMap),
}

/// Result of submitting one application I/O.
#[derive(Debug, Clone)]
pub struct Submitted {
    /// The queued fabric-level sub-I/O ids (one per replica for placed
    /// writes; `[io.id]` in direct mode). Work requests carry these ids.
    pub sub_ids: Vec<u64>,
    /// Every replica is dead: nothing was queued, the caller must take the
    /// disk path.
    pub disk_fallback: bool,
}

/// One planned post: a doorbell chain bound to a concrete QP.
#[derive(Debug)]
pub struct PostChain {
    pub qp: QpId,
    pub node: NodeId,
    pub wrs: Vec<WorkRequest>,
    /// Serialized CPU consumed on the drain path up to (and including)
    /// this chain's post — backends posting with a cost model schedule the
    /// chain at `drain_start + cpu_offset_ns`.
    pub cpu_offset_ns: u64,
}

/// Result of draining the sharded queues.
#[derive(Debug, Default)]
pub struct DrainOut {
    pub chains: Vec<PostChain>,
    /// Total serialized CPU of this drain (merge scans + posting).
    pub cpu_ns: u64,
    pub merged_ios: u64,
    /// Times the admission window blocked or truncated a shard drain.
    pub admission_blocked: u64,
}

/// An application I/O whose replication policy is satisfied.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RetiredIo {
    pub id: u64,
    /// No replica could serve it (reads: every attempt failed; writes:
    /// every replica write failed) — the caller owns the disk path.
    pub disk_fallback: bool,
    /// At least one read attempt failed over to a secondary replica.
    pub failed_over: bool,
}

/// Result of handling one work completion.
#[derive(Debug, Default)]
pub struct WcOut {
    pub retired: Vec<RetiredIo>,
    /// `(sub_id, parent_id)` for every sub-I/O that completed successfully
    /// in this WC — backends use it to hand read payloads back to the
    /// right application I/O.
    pub completed_subs: Vec<(u64, u64)>,
    /// `(sub_id, parent_id)` for every sub-I/O that failed *terminally*
    /// (no failover left) — backends use it to release per-sub resources.
    pub failed_subs: Vec<(u64, u64)>,
    /// Read sub-I/Os re-queued onto the next alive replica (failover).
    /// The caller should drain again to post them.
    pub requeued: u32,
}

/// Cumulative pipeline statistics.
#[derive(Debug, Default, Clone)]
pub struct EngineStats {
    pub submitted: u64,
    pub retired: u64,
    pub requeued: u64,
    pub disk_fallbacks: u64,
    pub admission_blocks: u64,
    pub merged_ios: u64,
    pub wqes: u64,
    pub posts: u64,
    /// Completions for a wr_id that was not outstanding (duplicates, or
    /// late deliveries after the WR already retired) — ignored, counted.
    pub duplicate_wcs: u64,
}

/// A queued fabric-level sub-I/O (placed mode).
#[derive(Debug, Clone, Copy)]
struct SubIo {
    parent: u64,
    addr: u64,
    len: u64,
    dir: Dir,
    thread: usize,
    t_submit: u64,
    /// Bitmask of replica nodes already attempted (failover skips them).
    attempted: u64,
}

/// Retirement state of one placed application I/O.
#[derive(Debug)]
struct Pending {
    remaining: u32,
    any_ok: bool,
    failed_over: bool,
}

/// A WR posted to the fabric and not yet completed. The map keyed by this
/// is the engine's idempotency ledger: the first completion for a wr_id
/// removes the entry, any later delivery of the same wr_id is a duplicate
/// and is dropped before it can touch the window or the retirement state.
#[derive(Debug, Clone, Copy)]
struct PostedWr {
    bytes: u64,
    t_post: u64,
}

/// The unified submit → merge → batch → admit → retire pipeline.
#[derive(Debug)]
pub struct IoEngine {
    batch: BatchMode,
    limits: BatchLimits,
    channels: ChannelMap,
    /// One read/write merge-queue pair per QP (global QP id indexing).
    shards: Vec<MergeQueues>,
    regulator: Regulator,
    routing: Routing,
    costs: EngineCosts,
    next_wr_id: u64,
    next_sub_id: u64,
    /// Rotating start shard for drains: when the admission window closes
    /// mid-drain, the next drain starts one shard later, so low-numbered
    /// QPs cannot starve the rest under a tight window.
    drain_cursor: usize,
    subs: FxHashMap<u64, SubIo>,
    pending: FxHashMap<u64, Pending>,
    /// wr_id → posted bytes + post time (idempotency ledger + RTT).
    outstanding: FxHashMap<u64, PostedWr>,
    pub stats: EngineStats,
}

impl IoEngine {
    pub fn new(
        batch: BatchMode,
        limits: BatchLimits,
        nodes: usize,
        qps_per_node: usize,
        window_bytes: Option<u64>,
        costs: EngineCosts,
    ) -> Self {
        let channels = ChannelMap::new(nodes, qps_per_node);
        let shards = (0..channels.total_qps())
            .map(|_| MergeQueues::new())
            .collect();
        let regulator = match window_bytes {
            Some(w) => Regulator::static_window(w),
            None => Regulator::unlimited(),
        };
        Self {
            batch,
            limits,
            channels,
            shards,
            regulator,
            routing: Routing::Direct,
            costs,
            next_wr_id: 1,
            next_sub_id: 1,
            drain_cursor: 0,
            subs: FxHashMap::default(),
            pending: FxHashMap::default(),
            outstanding: FxHashMap::default(),
            stats: EngineStats::default(),
        }
    }

    /// Build from a full stack design point (how the sim backend does it).
    pub fn from_stack(stack: &StackConfig, nodes: usize, costs: EngineCosts) -> Self {
        Self::new(
            stack.batch,
            stack.limits,
            nodes,
            stack.qps_per_node,
            stack.window_bytes,
            costs,
        )
    }

    /// Enable placed routing: replica fan-out, read failover, disk signal.
    pub fn with_placement(mut self, map: NodeMap) -> Self {
        assert_eq!(
            map.nodes(),
            self.channels.nodes(),
            "NodeMap and channel topology disagree on cluster size"
        );
        assert!(map.nodes() <= 64, "failover bitmask supports up to 64 nodes");
        self.routing = Routing::Placed(map);
        self
    }

    pub fn regulator(&self) -> &Regulator {
        &self.regulator
    }

    /// Swap in a custom admission policy (the paper's §5.1 hook).
    pub fn set_regulator(&mut self, r: Regulator) {
        self.regulator = r;
    }

    pub fn channels(&self) -> &ChannelMap {
        &self.channels
    }

    pub fn node_map(&self) -> Option<&NodeMap> {
        match &self.routing {
            Routing::Placed(m) => Some(m),
            Routing::Direct => None,
        }
    }

    pub fn node_map_mut(&mut self) -> Option<&mut NodeMap> {
        match &mut self.routing {
            Routing::Placed(m) => Some(m),
            Routing::Direct => None,
        }
    }

    /// Address-affine shard (= QP) selection for a request to `node`.
    pub fn shard_of(&self, node: NodeId, addr: u64) -> QpId {
        self.channels.select_by_addr(node, addr)
    }

    /// Requests currently queued across every shard.
    pub fn queued_ios(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.read.len() + s.write.len())
            .sum()
    }

    /// Requests currently queued in one direction.
    pub fn queued_ios_dir(&self, dir: Dir) -> usize {
        self.shards
            .iter()
            .map(|s| match dir {
                Dir::Read => s.read.len(),
                Dir::Write => s.write.len(),
            })
            .sum()
    }

    fn fresh_sub_id(&mut self) -> u64 {
        let id = self.next_sub_id;
        self.next_sub_id += 1;
        id
    }

    fn enqueue(&mut self, id: u64, node: NodeId, sub: &SubIo) {
        let qp = self.shard_of(node, sub.addr);
        self.shards[qp].of(sub.dir).push(AppIo {
            id,
            dir: sub.dir,
            node,
            addr: sub.addr,
            len: sub.len,
            thread: sub.thread,
            t_submit: sub.t_submit,
        });
    }

    /// Submit one application I/O into the pipeline (step 1 of the §5.1
    /// protocol: enqueue; the caller then triggers a drain, which is the
    /// merge-check step).
    pub fn submit(&mut self, io: AppIo) -> Submitted {
        self.stats.submitted += 1;
        enum Route {
            Direct,
            Disk,
            Targets(Vec<NodeId>),
        }
        let route = match (&self.routing, io.dir) {
            (Routing::Direct, _) => Route::Direct,
            (Routing::Placed(map), Dir::Write) => {
                let w = map.route_write(io.addr);
                if w.disk_fallback {
                    Route::Disk
                } else {
                    Route::Targets(w.targets)
                }
            }
            (Routing::Placed(map), Dir::Read) => match map.route_read(io.addr) {
                ReadRoute::Node(n) => Route::Targets(vec![n]),
                ReadRoute::DiskFallback => Route::Disk,
            },
        };
        match route {
            Route::Direct => {
                let qp = self.shard_of(io.node, io.addr);
                self.shards[qp].of(io.dir).push(io);
                Submitted {
                    sub_ids: vec![io.id],
                    disk_fallback: false,
                }
            }
            Route::Disk => {
                self.stats.disk_fallbacks += 1;
                Submitted {
                    sub_ids: Vec::new(),
                    disk_fallback: true,
                }
            }
            Route::Targets(targets) => {
                self.pending.insert(
                    io.id,
                    Pending {
                        remaining: targets.len() as u32,
                        any_ok: false,
                        failed_over: false,
                    },
                );
                let mut sub_ids = Vec::with_capacity(targets.len());
                for node in targets {
                    let sid = self.fresh_sub_id();
                    let sub = SubIo {
                        parent: io.id,
                        addr: io.addr,
                        len: io.len,
                        dir: io.dir,
                        thread: io.thread,
                        t_submit: io.t_submit,
                        attempted: 1u64 << node,
                    };
                    self.subs.insert(sid, sub);
                    self.enqueue(sid, node, &sub);
                    sub_ids.push(sid);
                }
                Submitted {
                    sub_ids,
                    disk_fallback: false,
                }
            }
        }
    }

    /// Drain one direction through every shard, bounded by the admission
    /// window. Registers each posted WR with the regulator; the returned
    /// chains are ready for the backend to move.
    pub fn drain_dir(&mut self, dir: Dir, now: u64) -> DrainOut {
        let mut out = DrainOut::default();
        let n_shards = self.shards.len();
        let start = self.drain_cursor % n_shards;
        self.drain_cursor = self.drain_cursor.wrapping_add(1);
        for i in 0..n_shards {
            let qp = (start + i) % n_shards;
            if self.shards[qp].of(dir).is_empty() {
                continue;
            }
            let avail = self.regulator.available(now);
            if avail == 0 {
                out.admission_blocked += 1;
                break;
            }
            let drained = match self.shards[qp].of(dir).merge_check(avail) {
                MergeCheck::Drained(v) => v,
                MergeCheck::Blocked => {
                    // progress guarantee: a request larger than the window
                    // must not deadlock — once the pipe is fully drained,
                    // admit exactly the head request (a budget of its own
                    // length drains it and nothing behind it)
                    if self.regulator.in_flight() == 0 {
                        let head_len = self.shards[qp].of(dir).peek()[0].len;
                        match self.shards[qp].of(dir).merge_check(head_len) {
                            MergeCheck::Drained(v) => v,
                            _ => continue,
                        }
                    } else {
                        out.admission_blocked += 1;
                        continue;
                    }
                }
                MergeCheck::TakenByPeer => continue,
            };
            if !self.shards[qp].of(dir).is_empty() {
                // window closed mid-drain: the tail stays queued (and keeps
                // merging with later arrivals — the regulator's side benefit)
                out.admission_blocked += 1;
            }
            out.cpu_ns += self.costs.merge_check_base_ns
                + self.costs.merge_check_per_io_ns * drained.len() as u64;
            let node = self.channels.node_of(qp);
            let (chains, pstats) = plan(self.batch, &self.limits, drained, &mut self.next_wr_id);
            out.merged_ios += pstats.merged_ios;
            self.stats.wqes += pstats.wqes;
            self.stats.posts += pstats.posts;
            for chain in chains {
                debug_assert_eq!(chain.node, node, "shard {qp} planned a foreign node");
                for wr in &chain.wrs {
                    self.regulator.on_post(wr.wr_id, wr.len);
                    self.outstanding.insert(
                        wr.wr_id,
                        PostedWr {
                            bytes: wr.len,
                            t_post: now + out.cpu_ns,
                        },
                    );
                    out.cpu_ns += self.costs.post_wqe_cpu_ns;
                }
                out.cpu_ns += self.costs.mmio_cpu_ns;
                out.chains.push(PostChain {
                    qp,
                    node,
                    wrs: chain.wrs,
                    cpu_offset_ns: out.cpu_ns,
                });
            }
        }
        self.stats.merged_ios += out.merged_ios;
        self.stats.admission_blocks += out.admission_blocked;
        out
    }

    /// Drain both directions (reads first: page-ins are synchronous).
    pub fn drain_all(&mut self, now: u64) -> DrainOut {
        let mut out = self.drain_dir(Dir::Read, now);
        let w = self.drain_dir(Dir::Write, now + out.cpu_ns);
        for mut c in w.chains {
            c.cpu_offset_ns += out.cpu_ns;
            out.chains.push(c);
        }
        out.cpu_ns += w.cpu_ns;
        out.merged_ios += w.merged_ios;
        out.admission_blocked += w.admission_blocked;
        out
    }

    /// Handle one work completion: release the admission window, map the
    /// WR's sub-I/Os back to application I/Os, apply the replication
    /// policy, and fail reads over to the next alive replica on error.
    ///
    /// Idempotent and order-independent: retirement is keyed by wr_id, so
    /// duplicate, late, and reordered completions (a chaotic CQ delivers
    /// all three) are tolerated — a WR releases its window bytes and
    /// resolves its sub-I/Os exactly once, whatever the CQ does.
    pub fn on_wc(&mut self, wc: &Wc, now: u64) -> WcOut {
        let Some(posted) = self.outstanding.remove(&wc.wr_id) else {
            // duplicate or unknown wr_id: dropped before it can touch the
            // window accounting or retire anything twice
            self.stats.duplicate_wcs += 1;
            return WcOut::default();
        };
        debug_assert_eq!(posted.bytes, wc.len, "WC length disagrees with its WR");
        let rtt = now.saturating_sub(posted.t_post);
        self.regulator.on_complete(wc.wr_id, wc.len, rtt);
        let ok = wc.status == WcStatus::Success;

        let mut out = WcOut::default();
        if matches!(self.routing, Routing::Direct) {
            // direct mode: sub-I/Os *are* the application I/Os — retire
            // each exactly once, no replication policy to satisfy. An
            // error completion (direct mode has no failover) surfaces as
            // the disk-fallback signal so callers can tell it apart.
            for &id in &wc.app_ios {
                out.retired.push(RetiredIo {
                    id,
                    disk_fallback: !ok,
                    failed_over: false,
                });
                if ok {
                    out.completed_subs.push((id, id));
                } else {
                    self.stats.disk_fallbacks += 1;
                    out.failed_subs.push((id, id));
                }
            }
            self.stats.retired += wc.app_ios.len() as u64;
            return out;
        }

        for &sid in &wc.app_ios {
            let Some(sub) = self.subs.remove(&sid) else {
                continue; // duplicate-completion guard
            };
            if ok {
                out.completed_subs.push((sid, sub.parent));
            } else if sub.dir == Dir::Read {
                // failover: re-queue onto the next alive, untried replica
                let next = match &self.routing {
                    Routing::Placed(map) => {
                        match map.route_read_excluding(sub.addr, sub.attempted) {
                            ReadRoute::Node(n) => Some(n),
                            ReadRoute::DiskFallback => None,
                        }
                    }
                    Routing::Direct => unreachable!(),
                };
                if let Some(node) = next {
                    let mut retry = sub;
                    retry.attempted |= 1u64 << node;
                    self.subs.insert(sid, retry);
                    if let Some(p) = self.pending.get_mut(&sub.parent) {
                        p.failed_over = true;
                    }
                    self.enqueue(sid, node, &retry);
                    out.requeued += 1;
                    self.stats.requeued += 1;
                    continue;
                }
            }
            let Some(p) = self.pending.get_mut(&sub.parent) else {
                continue;
            };
            if ok {
                p.any_ok = true;
            } else {
                out.failed_subs.push((sid, sub.parent));
            }
            p.remaining -= 1;
            if p.remaining == 0 {
                let done = self.pending.remove(&sub.parent).expect("pending parent");
                let disk_fallback = !done.any_ok;
                if disk_fallback {
                    self.stats.disk_fallbacks += 1;
                }
                self.stats.retired += 1;
                out.retired.push(RetiredIo {
                    id: sub.parent,
                    disk_fallback,
                    failed_over: done.failed_over,
                });
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::node::NodeMap;
    use crate::fabric::OpKind;

    fn engine(nodes: usize, qps: usize, window: Option<u64>) -> IoEngine {
        IoEngine::new(
            BatchMode::Hybrid,
            BatchLimits::default(),
            nodes,
            qps,
            window,
            EngineCosts::free(),
        )
    }

    fn io(id: u64, dir: Dir, node: usize, addr: u64) -> AppIo {
        AppIo {
            id,
            dir,
            node,
            addr,
            len: 4096,
            thread: 0,
            t_submit: 0,
        }
    }

    fn wc_for(wr: &WorkRequest, status: WcStatus) -> Wc {
        Wc {
            wr_id: wr.wr_id,
            qp: 0,
            op: wr.op,
            len: wr.len,
            app_ios: wr.app_ios.clone(),
            status,
        }
    }

    /// Drain, then deliver every posted WR as a successful completion.
    fn complete_all(e: &mut IoEngine) -> Vec<RetiredIo> {
        let mut retired = Vec::new();
        loop {
            let out = e.drain_all(0);
            if out.chains.is_empty() {
                break;
            }
            for chain in out.chains {
                for wr in chain.wrs {
                    let r = e.on_wc(&wc_for(&wr, WcStatus::Success), 0);
                    retired.extend(r.retired);
                }
            }
        }
        retired
    }

    #[test]
    fn direct_submit_retires_through_pipeline() {
        let mut e = engine(2, 4, None);
        for i in 0..8 {
            let s = e.submit(io(i, Dir::Write, (i % 2) as usize, i * 4096));
            assert_eq!(s.sub_ids, vec![i]);
        }
        let retired = complete_all(&mut e);
        let mut ids: Vec<u64> = retired.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..8).collect::<Vec<_>>());
        assert_eq!(e.queued_ios(), 0);
        assert_eq!(e.regulator().in_flight(), 0);
    }

    #[test]
    fn adjacent_submissions_share_a_shard_and_merge() {
        let mut e = engine(1, 4, None);
        for i in 0..8u64 {
            e.submit(io(i, Dir::Write, 0, i * 4096)); // same 1 MiB region
        }
        let out = e.drain_all(0);
        assert_eq!(out.chains.len(), 1, "one shard, one chain");
        assert_eq!(out.merged_ios, 8, "all adjacent pages merged");
        assert!(out.chains[0].wrs[0].num_sge > 1);
    }

    #[test]
    fn distant_regions_spread_over_shards() {
        let mut e = engine(1, 4, None);
        for i in 0..8u64 {
            e.submit(io(i, Dir::Write, 0, i << SHARD_REGION_SHIFT));
        }
        let out = e.drain_all(0);
        let qps: std::collections::BTreeSet<_> = out.chains.iter().map(|c| c.qp).collect();
        assert_eq!(qps.len(), 4, "8 regions cover all 4 shards");
    }

    #[test]
    fn same_region_maps_to_stable_shard() {
        let e = engine(3, 4, None);
        let a = e.shard_of(1, 5 << SHARD_REGION_SHIFT);
        assert_eq!(a, e.shard_of(1, (5 << SHARD_REGION_SHIFT) + 4096));
        assert_eq!(e.channels().node_of(a), 1);
    }

    #[test]
    fn admission_window_bounds_posted_bytes() {
        let mut e = engine(1, 2, Some(8192));
        for i in 0..8u64 {
            e.submit(io(i, Dir::Write, 0, i * 4096));
        }
        let out = e.drain_all(0);
        let posted: u64 = out
            .chains
            .iter()
            .flat_map(|c| c.wrs.iter())
            .map(|w| w.len)
            .sum();
        assert!(posted <= 8192, "posted {posted} > window");
        assert_eq!(e.regulator().in_flight(), posted);
        assert!(out.admission_blocked > 0);
        // completing releases the window and the rest drains
        let mut done = 0;
        for chain in out.chains {
            for wr in chain.wrs {
                done += e.on_wc(&wc_for(&wr, WcStatus::Success), 0).retired.len();
            }
        }
        done += complete_all(&mut e).len();
        assert_eq!(done, 8);
    }

    #[test]
    fn oversized_request_has_progress_guarantee() {
        let mut e = engine(1, 1, Some(4096));
        let mut big = io(1, Dir::Write, 0, 0);
        big.len = 1 << 20;
        e.submit(big);
        // backlog behind the oversized head must NOT ride along with it
        e.submit(io(2, Dir::Write, 0, 1 << 21));
        let first = e.drain_all(0);
        let posted: u64 = first
            .chains
            .iter()
            .flat_map(|c| c.wrs.iter())
            .map(|w| w.len)
            .sum();
        assert_eq!(posted, 1 << 20, "exactly the oversized head admitted");
        assert_eq!(e.queued_ios(), 1, "the small request stays queued");
        let mut done = 0;
        for chain in first.chains {
            for wr in chain.wrs {
                done += e.on_wc(&wc_for(&wr, WcStatus::Success), 0).retired.len();
            }
        }
        done += complete_all(&mut e).len();
        assert_eq!(done, 2, "both writes complete");
    }

    #[test]
    fn placed_write_fans_out_and_retires_once() {
        let map = NodeMap::new(3, 2, 1 << 20);
        let mut e = engine(3, 2, None).with_placement(map);
        let s = e.submit(io(42, Dir::Write, 0, 0));
        assert_eq!(s.sub_ids.len(), 2, "two replicas queued");
        let out = e.drain_all(0);
        let wrs: Vec<WorkRequest> = out.chains.into_iter().flat_map(|c| c.wrs).collect();
        assert_eq!(wrs.len(), 2);
        // first replica completing does NOT retire the io
        let r1 = e.on_wc(&wc_for(&wrs[0], WcStatus::Success), 0);
        assert!(r1.retired.is_empty(), "replication not yet satisfied");
        let r2 = e.on_wc(&wc_for(&wrs[1], WcStatus::Success), 0);
        assert_eq!(r2.retired.len(), 1);
        assert_eq!(r2.retired[0].id, 42);
        assert!(!r2.retired[0].disk_fallback);
    }

    #[test]
    fn placed_read_fails_over_to_next_replica() {
        let map = NodeMap::new(3, 2, 1 << 20);
        let mut e = engine(3, 2, None).with_placement(map);
        e.submit(io(7, Dir::Read, 0, 0)); // primary = node 0
        let out = e.drain_all(0);
        let wr = out.chains.into_iter().flat_map(|c| c.wrs).next().unwrap();
        assert_eq!(wr.node, 0);
        // primary dies mid-flight: error completion triggers failover
        e.node_map_mut().unwrap().set_alive(0, false);
        let r = e.on_wc(&wc_for(&wr, WcStatus::Error), 0);
        assert!(r.retired.is_empty());
        assert_eq!(r.requeued, 1);
        // the retry is queued for the secondary replica (node 1)
        let out2 = e.drain_all(0);
        let wr2 = out2.chains.into_iter().flat_map(|c| c.wrs).next().unwrap();
        assert_eq!(wr2.node, 1);
        let r2 = e.on_wc(&wc_for(&wr2, WcStatus::Success), 0);
        assert_eq!(r2.retired.len(), 1);
        assert!(r2.retired[0].failed_over);
        assert!(!r2.retired[0].disk_fallback);
    }

    #[test]
    fn placed_read_all_replicas_failed_signals_disk() {
        let map = NodeMap::new(2, 2, 1 << 20);
        let mut e = engine(2, 1, None).with_placement(map);
        e.submit(io(9, Dir::Read, 0, 0));
        let out = e.drain_all(0);
        let wr = out.chains.into_iter().flat_map(|c| c.wrs).next().unwrap();
        e.node_map_mut().unwrap().set_alive(0, false);
        let r = e.on_wc(&wc_for(&wr, WcStatus::Error), 0);
        assert_eq!(r.requeued, 1, "fails over to node 1 first");
        let out2 = e.drain_all(0);
        let wr2 = out2.chains.into_iter().flat_map(|c| c.wrs).next().unwrap();
        e.node_map_mut().unwrap().set_alive(1, false);
        let r2 = e.on_wc(&wc_for(&wr2, WcStatus::Error), 0);
        assert_eq!(r2.retired.len(), 1);
        assert!(r2.retired[0].disk_fallback, "all replicas dead -> disk");
    }

    #[test]
    fn placed_submit_with_dead_cluster_signals_disk_immediately() {
        let mut map = NodeMap::new(2, 2, 1 << 20);
        map.set_alive(0, false);
        map.set_alive(1, false);
        let mut e = engine(2, 1, None).with_placement(map);
        let s = e.submit(io(1, Dir::Write, 0, 0));
        assert!(s.disk_fallback && s.sub_ids.is_empty());
        let s = e.submit(io(2, Dir::Read, 0, 0));
        assert!(s.disk_fallback);
        assert_eq!(e.stats.disk_fallbacks, 2);
        assert_eq!(e.queued_ios(), 0);
    }

    #[test]
    fn placed_write_partial_replica_failure_still_retires_remote() {
        let map = NodeMap::new(2, 2, 1 << 20);
        let mut e = engine(2, 1, None).with_placement(map);
        e.submit(io(5, Dir::Write, 0, 0));
        let out = e.drain_all(0);
        let wrs: Vec<WorkRequest> = out.chains.into_iter().flat_map(|c| c.wrs).collect();
        assert_eq!(wrs.len(), 2);
        let r1 = e.on_wc(&wc_for(&wrs[0], WcStatus::Error), 0);
        assert!(r1.retired.is_empty());
        let r2 = e.on_wc(&wc_for(&wrs[1], WcStatus::Success), 0);
        assert_eq!(r2.retired.len(), 1);
        assert!(!r2.retired[0].disk_fallback, "one replica survived");
    }

    #[test]
    fn duplicate_wc_retires_once_direct_mode() {
        let mut e = engine(1, 1, Some(16 * 4096));
        e.submit(io(1, Dir::Write, 0, 0));
        let out = e.drain_all(0);
        let wr = out.chains.into_iter().flat_map(|c| c.wrs).next().unwrap();
        let wc = wc_for(&wr, WcStatus::Success);
        let r1 = e.on_wc(&wc, 0);
        assert_eq!(r1.retired.len(), 1);
        // the CQ delivers the same completion again: dropped, counted
        let r2 = e.on_wc(&wc, 0);
        assert!(r2.retired.is_empty(), "duplicate WC must not retire");
        assert!(r2.completed_subs.is_empty());
        assert_eq!(e.stats.duplicate_wcs, 1);
        assert_eq!(e.stats.retired, 1);
        assert_eq!(e.regulator().in_flight(), 0, "window released once");
    }

    #[test]
    fn duplicate_and_reordered_wcs_placed_mode() {
        let map = NodeMap::new(3, 2, 1 << 20);
        let mut e = engine(3, 2, Some(64 * 4096)).with_placement(map);
        for i in 0..4u64 {
            e.submit(io(i, Dir::Write, 0, i * 4096));
        }
        let out = e.drain_all(0);
        let wrs: Vec<WorkRequest> = out.chains.into_iter().flat_map(|c| c.wrs).collect();
        // deliver in reverse order, each twice
        let mut retired = Vec::new();
        for wr in wrs.iter().rev() {
            let wc = wc_for(wr, WcStatus::Success);
            retired.extend(e.on_wc(&wc, 0).retired);
            let dup = e.on_wc(&wc, 0);
            assert!(dup.retired.is_empty() && dup.completed_subs.is_empty());
        }
        let mut ids: Vec<u64> = retired.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1, 2, 3], "each io retired exactly once");
        assert_eq!(e.stats.duplicate_wcs, wrs.len() as u64);
        assert_eq!(e.regulator().in_flight(), 0);
    }

    #[test]
    fn error_completions_keep_window_balanced() {
        let map = NodeMap::new(2, 2, 1 << 20);
        let mut e = engine(2, 1, Some(8 * 4096)).with_placement(map);
        for i in 0..4u64 {
            e.submit(io(i, Dir::Write, 0, i * 4096));
        }
        let out = e.drain_all(0);
        for chain in out.chains {
            for wr in chain.wrs {
                // every completion errors; window must still drain to zero
                e.on_wc(&wc_for(&wr, WcStatus::Error), 0);
            }
        }
        assert_eq!(e.regulator().in_flight(), 0, "error WCs release bytes");
        assert_eq!(e.stats.retired, 4, "failed writes still retire");
        assert_eq!(e.stats.disk_fallbacks, 4);
    }

    /// Property-style check: random mixed traffic through the full
    /// pipeline conserves every application I/O exactly once and never
    /// exceeds the admission window in flight.
    #[test]
    fn prop_pipeline_conserves_ios_under_window() {
        use crate::util::rng::Pcg32;
        let window = 16 * 4096;
        let map = NodeMap::new(4, 2, 1 << 20);
        let mut e = engine(4, 4, Some(window)).with_placement(map);
        let mut rng = Pcg32::new(0xE761E);
        let mut in_flight: Vec<WorkRequest> = Vec::new();
        let mut retired = std::collections::BTreeSet::new();
        let total = 400u64;
        let mut submitted = 0u64;
        while (retired.len() as u64) < total {
            if submitted < total && rng.gen_bool(0.5) {
                let dir = if rng.gen_bool(0.3) { Dir::Read } else { Dir::Write };
                let addr = rng.gen_below(1 << 26) / 4096 * 4096;
                e.submit(io(submitted, dir, 0, addr));
                submitted += 1;
            }
            let out = e.drain_all(0);
            for c in out.chains {
                in_flight.extend(c.wrs);
            }
            assert!(
                e.regulator().in_flight() <= window,
                "window exceeded: {}",
                e.regulator().in_flight()
            );
            if !in_flight.is_empty() {
                let i = rng.gen_below(in_flight.len() as u64) as usize;
                let wr = in_flight.swap_remove(i);
                let r = e.on_wc(&wc_for(&wr, WcStatus::Success), 0);
                for ret in r.retired {
                    assert!(retired.insert(ret.id), "double retire of {}", ret.id);
                }
            }
        }
        assert_eq!(retired.len() as u64, total);
        assert_eq!(e.queued_ios(), 0);
        assert_eq!(e.regulator().in_flight(), 0);
    }

    #[test]
    fn drain_charges_serialized_cpu_with_cost_model() {
        let mut e = IoEngine::new(
            BatchMode::Single,
            BatchLimits::default(),
            1,
            1,
            None,
            EngineCosts {
                post_wqe_cpu_ns: 100,
                mmio_cpu_ns: 10,
                merge_check_base_ns: 5,
                merge_check_per_io_ns: 1,
            },
        );
        for i in 0..3u64 {
            e.submit(io(i, Dir::Write, 0, i << SHARD_REGION_SHIFT));
        }
        let out = e.drain_all(0);
        // scan: 5 + 3*1; per WR: 100 + 10 MMIO each (Single mode)
        assert_eq!(out.cpu_ns, 8 + 3 * 110);
        assert!(out.chains.windows(2).all(|w| w[0].cpu_offset_ns < w[1].cpu_offset_ns));
        assert_eq!(out.chains.last().unwrap().cpu_offset_ns, out.cpu_ns);
    }

    #[test]
    fn reads_and_writes_drain_independently() {
        let mut e = engine(1, 1, None);
        e.submit(io(1, Dir::Read, 0, 0));
        e.submit(io(2, Dir::Write, 0, 4096));
        let r = e.drain_dir(Dir::Read, 0);
        assert_eq!(r.chains.len(), 1);
        assert_eq!(r.chains[0].wrs[0].op, OpKind::Read);
        let w = e.drain_dir(Dir::Write, 0);
        assert_eq!(w.chains.len(), 1);
        assert_eq!(w.chains[0].wrs[0].op, OpKind::Write);
    }
}
