//! Multi-QP channel management (paper §6.1 "Multi-channel optimization").
//!
//! RDMAbox opens K QPs ("channels") per remote node, each with its own CQ
//! and context, to engage multiple NIC processing units and avoid the
//! false-synchronization of shared QPs. K is configurable at init time;
//! the paper finds K=4 best on ConnectX-3 (beyond that the NIC's QP-context
//! cache starts to thrash — Fig 11 K=8).

use crate::fabric::{CqId, NodeId, QpId};

/// Address bits defining the channel-affinity region (1 MiB): requests in
/// the same region stay on one channel (preserving merge adjacency),
/// different regions spread over the node's channels. Shared by
/// [`ChannelMap::select_by_addr`] and the engine's shard routing so the
/// two can never disagree.
pub const SHARD_REGION_SHIFT: u32 = 20;

/// The channel topology: how QPs/CQs map to remote nodes.
#[derive(Debug, Clone)]
pub struct ChannelMap {
    nodes: usize,
    qps_per_node: usize,
    /// SCQ(M) topology: if Some(m), all channels share `m` CQs instead of
    /// one CQ per QP.
    shared_cqs: Option<usize>,
    /// Round-robin cursor per node.
    cursors: Vec<usize>,
}

impl ChannelMap {
    pub fn new(nodes: usize, qps_per_node: usize) -> Self {
        assert!(nodes > 0 && qps_per_node > 0);
        Self {
            nodes,
            qps_per_node,
            shared_cqs: None,
            cursors: vec![0; nodes],
        }
    }

    /// SCQ(M): keep the per-node QPs but funnel all completions into M
    /// shared CQs (LITE-style design point, §6.2).
    pub fn with_shared_cqs(mut self, m: usize) -> Self {
        assert!(m > 0);
        self.shared_cqs = Some(m);
        self
    }

    pub fn nodes(&self) -> usize {
        self.nodes
    }

    pub fn qps_per_node(&self) -> usize {
        self.qps_per_node
    }

    pub fn total_qps(&self) -> usize {
        self.nodes * self.qps_per_node
    }

    pub fn total_cqs(&self) -> usize {
        match self.shared_cqs {
            Some(m) => m,
            None => self.total_qps(),
        }
    }

    pub fn is_shared(&self) -> bool {
        self.shared_cqs.is_some()
    }

    /// Global QP id for channel `k` of `node`.
    pub fn qp_of(&self, node: NodeId, k: usize) -> QpId {
        debug_assert!(node < self.nodes && k < self.qps_per_node);
        node * self.qps_per_node + k
    }

    /// The remote node a QP connects to.
    pub fn node_of(&self, qp: QpId) -> NodeId {
        qp / self.qps_per_node
    }

    /// CQ a QP's completions land in.
    pub fn cq_of(&self, qp: QpId) -> CqId {
        match self.shared_cqs {
            Some(m) => qp % m,
            None => qp,
        }
    }

    /// Select the next QP for a post to `node`.
    ///
    /// Round-robin across the node's channels; requests for the same
    /// contiguous region may land on different channels, which is fine —
    /// ordering across merged WRs is not required (each WR completes its
    /// own app I/Os) and spreading engages more NIC PUs.
    pub fn select(&mut self, node: NodeId) -> QpId {
        let k = self.cursors[node];
        self.cursors[node] = (k + 1) % self.qps_per_node;
        self.qp_of(node, k)
    }

    /// Deterministic address-affine selection: keeps a region on one
    /// channel. This is the engine's shard-routing function.
    pub fn select_by_addr(&self, node: NodeId, addr: u64) -> QpId {
        let k = (addr >> SHARD_REGION_SHIFT) as usize % self.qps_per_node;
        self.qp_of(node, k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qp_ids_partition_by_node() {
        let m = ChannelMap::new(3, 4);
        assert_eq!(m.total_qps(), 12);
        assert_eq!(m.qp_of(0, 0), 0);
        assert_eq!(m.qp_of(2, 3), 11);
        assert_eq!(m.node_of(11), 2);
        assert_eq!(m.node_of(4), 1);
    }

    #[test]
    fn per_qp_cqs_by_default() {
        let m = ChannelMap::new(2, 2);
        assert_eq!(m.total_cqs(), 4);
        assert!(!m.is_shared());
        for qp in 0..4 {
            assert_eq!(m.cq_of(qp), qp);
        }
    }

    #[test]
    fn shared_cqs_funnel() {
        let m = ChannelMap::new(4, 2).with_shared_cqs(2);
        assert_eq!(m.total_cqs(), 2);
        assert!(m.is_shared());
        for qp in 0..8 {
            assert!(m.cq_of(qp) < 2);
        }
        // both shared CQs are used
        let used: std::collections::BTreeSet<_> = (0..8).map(|q| m.cq_of(q)).collect();
        assert_eq!(used.len(), 2);
    }

    #[test]
    fn round_robin_covers_all_channels() {
        let mut m = ChannelMap::new(1, 4);
        let picks: Vec<QpId> = (0..8).map(|_| m.select(0)).collect();
        assert_eq!(picks, vec![0, 1, 2, 3, 0, 1, 2, 3]);
    }

    #[test]
    fn round_robin_is_per_node() {
        let mut m = ChannelMap::new(2, 2);
        assert_eq!(m.select(0), 0);
        assert_eq!(m.select(1), 2);
        assert_eq!(m.select(0), 1);
        assert_eq!(m.select(1), 3);
    }

    #[test]
    fn addr_affine_selection_is_stable() {
        let m = ChannelMap::new(1, 4);
        let a = m.select_by_addr(0, 5 << 20);
        assert_eq!(a, m.select_by_addr(0, 5 << 20));
        assert!(a < 4);
    }
}
