//! [`EngineSpec`] — the one construction surface of the coordinator.
//!
//! Every backend builds its pipeline from the same spec: the discrete-event
//! fabric, the chaos fabric and the live loopback client all call
//! [`crate::coordinator::engine::IoEngine::build`] with one of these, so a
//! design point is described once and runs everywhere. The spec replaces
//! the old constructor zoo (`IoEngine::new` positional args,
//! `with_resync`/`with_donor_election` chains, `new_placed_*` fabric
//! variants): features are named fields with validated dependencies
//! (election ⇒ resync ⇒ replication), not an ordering of method calls.

use crate::coordinator::batching::{BatchLimits, BatchMode};
use crate::coordinator::engine::{EngineCosts, SHARD_REGION_SHIFT};
use crate::coordinator::StackConfig;

/// Default chunk size of resync repair copies — well under every window
/// the examples/tests configure, so repair traffic cannot monopolize (or
/// overshoot) the admission window.
pub const DEFAULT_RESYNC_CHUNK: u64 = 64 * 1024;

/// A complete, validated description of one engine instance: batching,
/// topology, admission window, placement/replication, recovery features
/// and the multi-tenant QoS weights. Construct with [`EngineSpec::new`]
/// (or [`EngineSpec::from_stack`] for a paper design point), refine with
/// the builder methods, then hand to `IoEngine::build`,
/// `LiveBox::build` or `ChaosFabric::build`.
#[derive(Debug, Clone)]
pub struct EngineSpec {
    /// Batch planner mode (Single / MR / Doorbell / Hybrid).
    pub batch: BatchMode,
    /// NIC / verbs-layer limits on merged WRs and doorbell chains.
    pub limits: BatchLimits,
    /// Remote nodes in the cluster.
    pub nodes: usize,
    /// QPs (channels) per remote node.
    pub qps_per_node: usize,
    /// Admission-control window in bytes; `None` = unlimited.
    pub window_bytes: Option<u64>,
    /// CPU cost model (the sim fills this from calibration; live backends
    /// run [`EngineCosts::free`]).
    pub costs: EngineCosts,
    /// `Some(r)` attaches placement routing: writes fan out to `r`
    /// replicas, reads fail over across them.
    pub replicas: Option<usize>,
    /// Stripe width of the placement map (bytes).
    pub stripe_bytes: u64,
    /// `Some(chunk)` enables the epoch-based resync protocol with the
    /// given repair-copy chunk size. Requires replication.
    pub resync_chunk: Option<u64>,
    /// Enables epoch-vector donor election on top of resync.
    pub election: bool,
    /// QoS weights, one per tenant; a single entry means single-tenant
    /// operation (the exact pre-QoS FIFO/admission behaviour).
    pub tenant_weights: Vec<u64>,
    /// `Some(cap)` enables the pinning-free memory path: a clock MR cache
    /// of registration spans with lazy registration on first touch and
    /// batched deregistration, holding at most `cap` pinned bytes
    /// ([`crate::coordinator::mr_cache::MrCache`]). `None` keeps the
    /// static MR strategies exactly as before.
    pub mr_cache_bytes: Option<u64>,
    /// `Some((engine_id, engines))` makes this engine member
    /// `engine_id` of an `engines`-strong multi-engine cluster: write
    /// epochs are minted from the engine's interleaved stream and the
    /// anti-entropy plane ([`crate::coordinator::gossip`]) exchanges
    /// epoch vectors, node states and disk-span ownership with peers.
    /// Requires replication (peer engines coordinate over a shared
    /// replica set). `None` keeps the exact single-engine behaviour.
    pub gossip: Option<(usize, usize)>,
    /// `Some((timeout_ns, max_retries))` enables completion-deadline
    /// recovery: every posted WR is armed with a deadline `timeout_ns`
    /// after its post; on expiry the engine synthesizes a local
    /// timeout-WC (releasing the admission window and rerouting through
    /// the ordinary failover paths), retrying a timed-out read up to
    /// `max_retries` times under capped jittered backoff before it
    /// falls back like any terminal error. Repeated timeouts trip the
    /// per-QP `Ok → Error → Resetting → Ok` state machine. `None` keeps
    /// the pre-deadline behaviour: a completion that never arrives
    /// hangs its request forever.
    pub deadlines: Option<(u64, u32)>,
}

impl EngineSpec {
    /// Spec for a direct-routing engine over `nodes` remote nodes, one
    /// channel each: hybrid batching, default limits, unlimited window,
    /// zero cost model, no placement, a single tenant.
    pub fn new(nodes: usize) -> Self {
        Self {
            batch: BatchMode::Hybrid,
            limits: BatchLimits::default(),
            nodes,
            qps_per_node: 1,
            window_bytes: None,
            costs: EngineCosts::free(),
            replicas: None,
            stripe_bytes: 1 << SHARD_REGION_SHIFT,
            resync_chunk: None,
            election: false,
            tenant_weights: vec![1],
            mr_cache_bytes: None,
            gossip: None,
            deadlines: None,
        }
    }

    /// Spec carrying a [`StackConfig`] design point's engine-relevant
    /// knobs (batching, limits, channels, window). MR / polling / copy
    /// semantics stay with the fabric driving the engine.
    pub fn from_stack(stack: &StackConfig, nodes: usize) -> Self {
        Self {
            batch: stack.batch,
            limits: stack.limits,
            qps_per_node: stack.qps_per_node,
            window_bytes: stack.window_bytes,
            ..Self::new(nodes)
        }
    }

    pub fn batch(mut self, b: BatchMode) -> Self {
        self.batch = b;
        self
    }

    pub fn limits(mut self, l: BatchLimits) -> Self {
        self.limits = l;
        self
    }

    pub fn qps(mut self, k: usize) -> Self {
        self.qps_per_node = k;
        self
    }

    pub fn window(mut self, w: Option<u64>) -> Self {
        self.window_bytes = w;
        self
    }

    pub fn costs(mut self, c: EngineCosts) -> Self {
        self.costs = c;
        self
    }

    /// Attach placement routing: `replicas` copies per stripe.
    pub fn replicated(mut self, replicas: usize) -> Self {
        self.replicas = Some(replicas);
        self
    }

    pub fn stripe(mut self, bytes: u64) -> Self {
        self.stripe_bytes = bytes;
        self
    }

    /// Enable the epoch-based resync protocol (requires [`replicated`]).
    ///
    /// [`replicated`]: EngineSpec::replicated
    pub fn resync(mut self, chunk: u64) -> Self {
        self.resync_chunk = Some(chunk);
        self
    }

    /// Enable epoch-vector donor election (requires [`resync`]).
    ///
    /// [`resync`]: EngineSpec::resync
    pub fn election(mut self) -> Self {
        self.election = true;
        self
    }

    /// Enable the dynamic MR cache with a pinned-bytes cap (the
    /// pinning-free memory path — lazy registration, clock eviction,
    /// deferred dereg batches).
    pub fn mr_cache(mut self, cap_bytes: u64) -> Self {
        self.mr_cache_bytes = Some(cap_bytes);
        self
    }

    /// Join a multi-engine cluster as member `engine_id` of `engines`
    /// (requires [`replicated`]): enables interleaved epoch minting and
    /// the inter-engine gossip plane.
    ///
    /// [`replicated`]: EngineSpec::replicated
    pub fn gossip(mut self, engine_id: usize, engines: usize) -> Self {
        self.gossip = Some((engine_id, engines));
        self
    }

    /// Arm completion deadlines: a posted WR that has not completed
    /// `timeout_ns` after its post is retired locally as a timeout
    /// (window released, request rerouted / retried up to `max_retries`
    /// times with capped jittered backoff). Also enables the per-QP
    /// error/reset state machine driven by consecutive timeouts.
    pub fn deadlines(mut self, timeout_ns: u64, max_retries: u32) -> Self {
        self.deadlines = Some((timeout_ns, max_retries));
        self
    }

    /// Register the QoS tenants by weight. More than one entry switches
    /// the engine to hierarchical admission + weighted-fair drain; the
    /// default single entry keeps the exact single-tenant fast path.
    pub fn tenants(mut self, weights: &[u64]) -> Self {
        self.tenant_weights = weights.to_vec();
        self
    }

    /// Panics on an inconsistent spec — the same dependency rules the old
    /// constructor chain enforced by ordering, now checked up front.
    pub fn validate(&self) {
        assert!(self.nodes >= 1, "spec: at least one node");
        assert!(self.qps_per_node >= 1, "spec: at least one QP per node");
        if let Some(w) = self.window_bytes {
            assert!(w > 0, "spec: zero-byte admission window admits nothing");
        }
        assert!(self.stripe_bytes > 0, "spec: stripe_bytes must be nonzero");
        if let Some(r) = self.replicas {
            assert!(
                r >= 1 && r <= self.nodes,
                "spec: replicas {} out of range 1..={}",
                r,
                self.nodes
            );
        }
        if let Some(chunk) = self.resync_chunk {
            assert!(chunk > 0, "spec: resync chunk must be nonzero");
            assert!(
                self.replicas.is_some(),
                "spec: resync requires replication (call .replicated(r))"
            );
        }
        if self.election {
            assert!(
                self.resync_chunk.is_some(),
                "spec: donor election requires resync (call .resync(chunk))"
            );
        }
        if let Some(cap) = self.mr_cache_bytes {
            assert!(
                cap >= crate::coordinator::mr_cache::MR_SPAN_BYTES,
                "spec: MR cache cap {cap} pins less than one registration span ({})",
                crate::coordinator::mr_cache::MR_SPAN_BYTES
            );
            if let Some(w) = self.window_bytes {
                assert!(
                    cap >= w,
                    "spec: MR cache cap {cap} below the admission window {w} — \
                     in-flight bytes must stay registrable (spans pinned by \
                     posted WRs cannot all fit)"
                );
            }
        }
        if let Some((id, n)) = self.gossip {
            assert!(
                n >= 2,
                "spec: gossip cluster of {n} engine(s) — a single engine has \
                 no peers to gossip with"
            );
            assert!(id < n, "spec: gossip engine id {id} out of range 0..{n}");
            assert!(
                self.replicas.is_some(),
                "spec: gossip requires replication (call .replicated(r)) — \
                 peer engines coordinate over a shared replica set"
            );
        }
        if let Some((timeout_ns, max_retries)) = self.deadlines {
            assert!(
                timeout_ns > 0,
                "spec: zero-ns completion deadline times out every WR at its \
                 own post"
            );
            assert!(
                max_retries <= 64,
                "spec: deadline max_retries {max_retries} out of range 0..=64"
            );
            assert!(
                self.replicas.is_some(),
                "spec: deadlines require placed routing (call .replicated(r)) — \
                 a timeout-WC is rebuilt from the engine's sub ledger"
            );
        }
        assert!(!self.tenant_weights.is_empty(), "spec: at least one tenant");
        for (t, &w) in self.tenant_weights.iter().enumerate() {
            assert!(
                w >= 1 && w <= (1 << 20),
                "spec: tenant {t} weight {w} out of range 1..=2^20"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::FabricConfig;

    #[test]
    fn defaults_validate() {
        EngineSpec::new(1).validate();
        EngineSpec::new(3)
            .qps(4)
            .window(Some(7 << 20))
            .replicated(2)
            .resync(DEFAULT_RESYNC_CHUNK)
            .election()
            .tenants(&[3, 1])
            .mr_cache(16 << 20)
            .validate();
    }

    #[test]
    #[should_panic(expected = "pins less than one registration span")]
    fn mr_cache_below_one_span_is_rejected() {
        EngineSpec::new(1).mr_cache(4096).validate();
    }

    #[test]
    #[should_panic(expected = "below the admission window")]
    fn mr_cache_below_window_is_rejected() {
        EngineSpec::new(1)
            .window(Some(7 << 20))
            .mr_cache(1 << 20)
            .validate();
    }

    #[test]
    fn from_stack_carries_engine_knobs() {
        let cfg = FabricConfig::default();
        let stack = StackConfig::rdmabox(&cfg);
        let spec = EngineSpec::from_stack(&stack, 4);
        assert_eq!(spec.batch, stack.batch);
        assert_eq!(spec.qps_per_node, stack.qps_per_node);
        assert_eq!(spec.window_bytes, stack.window_bytes);
        assert_eq!(spec.nodes, 4);
        assert_eq!(spec.tenant_weights, vec![1]);
        spec.validate();
    }

    #[test]
    #[should_panic(expected = "resync requires replication")]
    fn resync_without_replication_is_rejected() {
        EngineSpec::new(2).resync(4096).validate();
    }

    #[test]
    #[should_panic(expected = "donor election requires resync")]
    fn election_without_resync_is_rejected() {
        EngineSpec::new(2).replicated(2).election().validate();
    }

    #[test]
    #[should_panic(expected = "weight 0 out of range")]
    fn zero_weight_is_rejected() {
        EngineSpec::new(1).tenants(&[1, 0]).validate();
    }

    #[test]
    #[should_panic(expected = "replicas 3 out of range")]
    fn more_replicas_than_nodes_is_rejected() {
        EngineSpec::new(2).replicated(3).validate();
    }

    // ISSUE 9 satellite: the rejection paths below had no coverage —
    // every guard in `validate` gets a test pinning its message.

    #[test]
    #[should_panic(expected = "at least one node")]
    fn zero_nodes_is_rejected() {
        EngineSpec::new(0).validate();
    }

    #[test]
    #[should_panic(expected = "at least one QP per node")]
    fn zero_qps_is_rejected() {
        EngineSpec::new(1).qps(0).validate();
    }

    #[test]
    #[should_panic(expected = "zero-byte admission window")]
    fn zero_byte_window_is_rejected() {
        EngineSpec::new(1).window(Some(0)).validate();
    }

    #[test]
    #[should_panic(expected = "stripe_bytes must be nonzero")]
    fn zero_stripe_is_rejected() {
        EngineSpec::new(1).stripe(0).validate();
    }

    #[test]
    #[should_panic(expected = "resync chunk must be nonzero")]
    fn zero_resync_chunk_is_rejected() {
        EngineSpec::new(2).replicated(2).resync(0).validate();
    }

    #[test]
    #[should_panic(expected = "at least one tenant")]
    fn empty_tenant_list_is_rejected() {
        EngineSpec::new(1).tenants(&[]).validate();
    }

    #[test]
    #[should_panic(expected = "out of range 1..=2^20")]
    fn oversized_tenant_weight_is_rejected() {
        EngineSpec::new(1).tenants(&[(1 << 20) + 1]).validate();
    }

    #[test]
    fn gossip_spec_validates_with_replication() {
        EngineSpec::new(3)
            .replicated(2)
            .resync(DEFAULT_RESYNC_CHUNK)
            .election()
            .gossip(0, 2)
            .validate();
        // election is optional: benches run gossip replicated-only
        EngineSpec::new(1).replicated(1).gossip(1, 2).validate();
    }

    #[test]
    #[should_panic(expected = "gossip requires replication")]
    fn gossip_without_replication_is_rejected() {
        EngineSpec::new(2).gossip(0, 2).validate();
    }

    #[test]
    #[should_panic(expected = "no peers to gossip with")]
    fn single_engine_gossip_cluster_is_rejected() {
        EngineSpec::new(2).replicated(2).gossip(0, 1).validate();
    }

    #[test]
    #[should_panic(expected = "engine id 2 out of range")]
    fn gossip_engine_id_out_of_range_is_rejected() {
        EngineSpec::new(2).replicated(2).gossip(2, 2).validate();
    }

    #[test]
    fn deadline_spec_validates() {
        EngineSpec::new(1).replicated(1).deadlines(500_000, 3).validate();
        // zero retries is legal: timeouts go straight to failover
        EngineSpec::new(2)
            .replicated(2)
            .deadlines(1_000_000, 0)
            .validate();
    }

    #[test]
    #[should_panic(expected = "deadlines require placed routing")]
    fn deadlines_without_placement_are_rejected() {
        EngineSpec::new(1).deadlines(500_000, 3).validate();
    }

    #[test]
    #[should_panic(expected = "zero-ns completion deadline")]
    fn zero_deadline_timeout_is_rejected() {
        EngineSpec::new(1).deadlines(0, 3).validate();
    }

    #[test]
    #[should_panic(expected = "max_retries 65 out of range")]
    fn oversized_deadline_retries_is_rejected() {
        EngineSpec::new(1).deadlines(500_000, 65).validate();
    }
}
