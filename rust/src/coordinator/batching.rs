//! Batch planner: turns a drained set of requests into posted chains
//! according to the configured batching approach (paper §5.1, Fig 3).
//!
//! * `Single` — every request is its own WR, its own post (one MMIO each).
//! * `BatchOnMr` — *adjacent* requests (contiguous remote addresses, same
//!   node, same direction) merge into one WR with multiple SGEs: fewer
//!   WQEs reach the NIC **and** fewer MMIOs cross PCIe.
//! * `Doorbell` — no merging; all requests to the same QP are chained into
//!   one doorbell post: one MMIO + (n−1) descriptor DMA reads, but the NIC
//!   still processes n WQEs.
//! * `Hybrid` — Batching-on-MR first, then doorbell-chain the surviving
//!   WRs. The paper's default: the two optimizations compose because they
//!   trigger on different conditions (adjacency vs mere co-residence in
//!   the queue).

use crate::fabric::{AppIo, WorkRequest};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatchMode {
    Single,
    BatchOnMr,
    Doorbell,
    Hybrid,
}

impl BatchMode {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "single" => Ok(Self::Single),
            "batch" | "batch-on-mr" => Ok(Self::BatchOnMr),
            "doorbell" => Ok(Self::Doorbell),
            "hybrid" => Ok(Self::Hybrid),
            other => Err(format!("unknown batch mode `{other}`")),
        }
    }

    pub fn merges(self) -> bool {
        matches!(self, Self::BatchOnMr | Self::Hybrid)
    }

    pub fn chains(self) -> bool {
        matches!(self, Self::Doorbell | Self::Hybrid)
    }
}

/// Limits imposed by the NIC / verbs layer.
#[derive(Debug, Clone, Copy)]
pub struct BatchLimits {
    /// Max scatter/gather entries per WR (merge width).
    pub max_sge: usize,
    /// Max WRs per doorbell chain.
    pub max_chain: usize,
    /// Max bytes per merged WR.
    pub max_wr_bytes: u64,
}

impl Default for BatchLimits {
    fn default() -> Self {
        Self {
            max_sge: 16,
            max_chain: 16,
            max_wr_bytes: 1 << 20,
        }
    }
}

/// One planned post: a chain of WRs to a single destination node. A chain
/// of length 1 is a plain single post. QP selection happens later (channel
/// layer) — planning is per *node*. Test-only: production paths use the
/// flat [`ChainSpan`] representation from [`plan_into`].
#[cfg(test)]
#[derive(Debug, Clone)]
pub struct PlannedChain {
    pub node: usize,
    pub wrs: Vec<WorkRequest>,
}

/// One planned post in the flat (arena) representation: the chain's WRs
/// are `wrs[start..end]` of the output buffer [`plan_into`] appended to.
/// Flat spans are what let the engine's drain path reuse one contiguous
/// WR buffer per drain instead of allocating a `Vec` per chain.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChainSpan {
    pub node: usize,
    pub start: usize,
    pub end: usize,
}

/// Reusable planner scratch: per-node grouping buffers that survive
/// across drains, so steady-state planning allocates nothing. The groups
/// keep their high-water capacity; `active` marks how many are in use for
/// the current call.
#[derive(Debug, Default)]
pub struct PlanArena {
    groups: Vec<(usize, Vec<AppIo>)>,
    active: usize,
}

/// Plan statistics, fed into the experiment counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PlanStats {
    /// Requests that were merged into a WR with >1 fragment.
    pub merged_ios: u64,
    /// WRs produced.
    pub wqes: u64,
    /// Posts (MMIOs) produced.
    pub posts: u64,
    /// WRs that ride a doorbell chain as non-head entries (descriptor DMA
    /// instead of MMIO).
    pub chained_wrs: u64,
}

/// Plan a drained batch. Input order is the FIFO drain order; output chains
/// preserve per-node arrival order of the head request so latency-sensitive
/// requests are not reordered behind later arrivals.
///
/// Allocating convenience wrapper around [`plan_into`], kept for the unit
/// suites; every production path calls the `_into` form with reused
/// buffers.
#[cfg(test)]
pub fn plan(
    mode: BatchMode,
    lim: &BatchLimits,
    mut ios: Vec<AppIo>,
    next_wr_id: &mut u64,
) -> (Vec<PlannedChain>, PlanStats) {
    let mut wrs = Vec::new();
    let mut spans = Vec::new();
    let mut arena = PlanArena::default();
    let stats = plan_into(
        mode,
        lim,
        &mut ios,
        next_wr_id,
        &mut wrs,
        &mut spans,
        &mut arena,
    );
    // spans are contiguous and ascending over `wrs`, so a single pass
    // carves the flat buffer into per-chain Vecs
    let mut out = Vec::with_capacity(spans.len());
    let mut iter = wrs.into_iter();
    for s in spans {
        out.push(PlannedChain {
            node: s.node,
            wrs: iter.by_ref().take(s.end - s.start).collect(),
        });
    }
    (out, stats)
}

/// Zero-allocation batch planning: drains `ios` (leaving it empty with its
/// capacity intact), appends the planned [`WorkRequest`]s to `wrs` and the
/// chain boundaries to `chains` (as index spans into `wrs`), grouping
/// through the reusable `arena`. At steady state — buffers warm, WRs
/// within the inline [`crate::util::idlist::INLINE_IDS`] merge width —
/// this performs no heap allocation at all.
pub fn plan_into(
    mode: BatchMode,
    lim: &BatchLimits,
    ios: &mut Vec<AppIo>,
    next_wr_id: &mut u64,
    wrs: &mut Vec<WorkRequest>,
    chains: &mut Vec<ChainSpan>,
    arena: &mut PlanArena,
) -> PlanStats {
    let mut stats = PlanStats::default();
    if ios.is_empty() {
        return stats;
    }
    // fast path: a lone request (the common light-load case — §5.1 "if a
    // request arrives alone, its thread posts a single RDMA I/O
    // immediately") skips grouping, sorting and chaining entirely.
    if ios.len() == 1 {
        let node = ios[0].node;
        let start = wrs.len();
        wrs.push(mk_wr(next_wr_id, &ios[..1]));
        ios.clear();
        stats.wqes = 1;
        stats.posts = 1;
        chains.push(ChainSpan {
            node,
            start,
            end: start + 1,
        });
        return stats;
    }

    // 1) group by destination node, preserving arrival order. Group
    // buffers are recycled from previous calls (`active` marks use).
    arena.active = 0;
    for io in ios.drain(..) {
        match arena.groups[..arena.active]
            .iter()
            .position(|(n, _)| *n == io.node)
        {
            Some(i) => arena.groups[i].1.push(io),
            None => {
                if arena.active == arena.groups.len() {
                    arena.groups.push((io.node, Vec::new()));
                }
                let g = &mut arena.groups[arena.active];
                g.0 = io.node;
                g.1.clear();
                g.1.push(io);
                arena.active += 1;
            }
        }
    }

    for gi in 0..arena.active {
        let node = arena.groups[gi].0;
        let group_start = wrs.len();
        // 2) merge adjacent requests (Batching-on-MR) if the mode allows:
        // sort by remote address within the drained set — this is the
        // "opportunistically looks for multiple adjacent requests" step;
        // after the sort every mergeable run is a contiguous slice.
        if mode.merges() {
            // tenant in the key keeps each tenant's mergeable runs
            // contiguous; a WR never mixes tenants (it bills to exactly
            // one per-tenant sub-window)
            arena.groups[gi]
                .1
                .sort_by_key(|io| (io.dir.op() as u8, io.tenant, io.addr));
            let g = &arena.groups[gi].1;
            let mut i = 0;
            while i < g.len() {
                let mut end_addr = g[i].addr + g[i].len;
                let mut bytes = g[i].len;
                let mut j = i + 1;
                while j < g.len()
                    && (j - i) < lim.max_sge
                    && g[j].dir == g[i].dir
                    && g[j].tenant == g[i].tenant
                    && g[j].addr == end_addr
                    && bytes + g[j].len <= lim.max_wr_bytes
                {
                    end_addr += g[j].len;
                    bytes += g[j].len;
                    j += 1;
                }
                if j - i > 1 {
                    stats.merged_ios += (j - i) as u64;
                }
                wrs.push(mk_wr(next_wr_id, &g[i..j]));
                stats.wqes += 1;
                i = j;
            }
        } else {
            for io in &arena.groups[gi].1 {
                wrs.push(mk_wr(next_wr_id, std::slice::from_ref(io)));
                stats.wqes += 1;
            }
        }

        // 3) chain into doorbell posts if the mode allows.
        if mode.chains() {
            let mut s = group_start;
            while s < wrs.len() {
                let e = (s + lim.max_chain).min(wrs.len());
                stats.posts += 1;
                stats.chained_wrs += (e - s - 1) as u64;
                chains.push(ChainSpan {
                    node,
                    start: s,
                    end: e,
                });
                s = e;
            }
        } else {
            for s in group_start..wrs.len() {
                stats.posts += 1;
                chains.push(ChainSpan {
                    node,
                    start: s,
                    end: s + 1,
                });
            }
        }
    }
    stats
}

fn mk_wr(next_wr_id: &mut u64, ios: &[AppIo]) -> WorkRequest {
    let id = *next_wr_id;
    *next_wr_id += 1;
    WorkRequest {
        wr_id: id,
        op: ios[0].dir.op(),
        node: ios[0].node,
        remote_addr: ios.iter().map(|io| io.addr).min().unwrap(),
        len: ios.iter().map(|io| io.len).sum(),
        num_sge: ios.len(),
        app_ios: ios.iter().map(|io| io.id).collect(),
        signaled: true,
        tenant: ios[0].tenant,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::Dir;
    use crate::util::prop::{self, cfg};

    fn io(id: u64, node: usize, addr: u64, len: u64, dir: Dir) -> AppIo {
        AppIo {
            id,
            dir,
            node,
            addr,
            len,
            thread: 0,
            t_submit: 0,
            tenant: 0,
        }
    }

    fn wio(id: u64, addr: u64) -> AppIo {
        io(id, 0, addr, 4096, Dir::Write)
    }

    #[test]
    fn single_mode_one_wr_one_post_each() {
        let mut id = 0;
        let (chains, st) = plan(
            BatchMode::Single,
            &BatchLimits::default(),
            vec![wio(1, 0), wio(2, 4096), wio(3, 8192)],
            &mut id,
        );
        assert_eq!(chains.len(), 3);
        assert_eq!(st.wqes, 3);
        assert_eq!(st.posts, 3);
        assert_eq!(st.merged_ios, 0);
        assert!(chains.iter().all(|c| c.wrs.len() == 1));
    }

    #[test]
    fn batch_on_mr_merges_adjacent() {
        let mut id = 0;
        let (chains, st) = plan(
            BatchMode::BatchOnMr,
            &BatchLimits::default(),
            vec![wio(1, 0), wio(2, 4096), wio(3, 8192), wio(4, 1 << 20)],
            &mut id,
        );
        // three adjacent merge into one WR; the distant one stays alone
        assert_eq!(st.wqes, 2);
        assert_eq!(st.posts, 2);
        assert_eq!(st.merged_ios, 3);
        let merged = chains.iter().find(|c| c.wrs[0].num_sge == 3).unwrap();
        assert_eq!(merged.wrs[0].len, 3 * 4096);
        assert_eq!(merged.wrs[0].app_ios, vec![1, 2, 3]);
    }

    #[test]
    fn doorbell_chains_without_reducing_wqes() {
        let mut id = 0;
        let ios: Vec<AppIo> = (0..5).map(|i| wio(i, i * 4096)).collect();
        let (chains, st) = plan(BatchMode::Doorbell, &BatchLimits::default(), ios, &mut id);
        assert_eq!(chains.len(), 1);
        assert_eq!(st.wqes, 5); // same number of RDMA I/Os as single
        assert_eq!(st.posts, 1); // but one MMIO
        assert_eq!(st.chained_wrs, 4);
    }

    #[test]
    fn hybrid_merges_then_chains() {
        let mut id = 0;
        // two adjacent + one distant -> 2 WRs -> 1 chain
        let (chains, st) = plan(
            BatchMode::Hybrid,
            &BatchLimits::default(),
            vec![wio(1, 0), wio(2, 4096), wio(3, 1 << 20)],
            &mut id,
        );
        assert_eq!(st.wqes, 2);
        assert_eq!(st.posts, 1);
        assert_eq!(chains[0].wrs.len(), 2);
    }

    #[test]
    fn different_nodes_never_merge_or_chain_together() {
        let mut id = 0;
        let (chains, st) = plan(
            BatchMode::Hybrid,
            &BatchLimits::default(),
            vec![
                io(1, 0, 0, 4096, Dir::Write),
                io(2, 1, 4096, 4096, Dir::Write),
            ],
            &mut id,
        );
        assert_eq!(chains.len(), 2);
        assert_eq!(st.wqes, 2);
        assert!(chains.iter().all(|c| c.wrs.len() == 1));
    }

    #[test]
    fn reads_and_writes_do_not_merge() {
        let mut id = 0;
        let (_, st) = plan(
            BatchMode::BatchOnMr,
            &BatchLimits::default(),
            vec![
                io(1, 0, 0, 4096, Dir::Write),
                io(2, 0, 4096, 4096, Dir::Read),
            ],
            &mut id,
        );
        assert_eq!(st.wqes, 2);
        assert_eq!(st.merged_ios, 0);
    }

    /// QoS invariant: adjacent requests of *different tenants* never
    /// merge into one WR — the whole WR bills to a single per-tenant
    /// sub-window — and every planned WR carries its owning tenant.
    #[test]
    fn different_tenants_never_merge() {
        let mut id = 0;
        let a = AppIo { tenant: 0, ..wio(1, 0) };
        let b = AppIo { tenant: 1, ..wio(2, 4096) };
        let c = AppIo { tenant: 1, ..wio(3, 8192) };
        let (chains, st) = plan(
            BatchMode::BatchOnMr,
            &BatchLimits::default(),
            vec![a, b, c],
            &mut id,
        );
        assert_eq!(st.wqes, 2, "tenant boundary splits the adjacent run");
        assert_eq!(st.merged_ios, 2, "same-tenant pair still merges");
        for ch in &chains {
            for w in &ch.wrs {
                let want = if w.app_ios.iter().any(|&i| i == 1) { 0 } else { 1 };
                assert_eq!(w.tenant, want, "WR carries its owning tenant");
            }
        }
    }

    #[test]
    fn max_sge_limits_merge_width() {
        let mut id = 0;
        let lim = BatchLimits {
            max_sge: 4,
            ..Default::default()
        };
        let ios: Vec<AppIo> = (0..10).map(|i| wio(i, i * 4096)).collect();
        let (_, st) = plan(BatchMode::BatchOnMr, &lim, ios, &mut id);
        assert_eq!(st.wqes, 3); // 4 + 4 + 2
    }

    #[test]
    fn max_chain_splits_doorbell() {
        let mut id = 0;
        let lim = BatchLimits {
            max_chain: 2,
            ..Default::default()
        };
        let ios: Vec<AppIo> = (0..5).map(|i| wio(i, i * 8192)).collect(); // non-adjacent
        let (chains, st) = plan(BatchMode::Hybrid, &lim, ios, &mut id);
        assert_eq!(chains.len(), 3); // 2+2+1
        assert_eq!(st.posts, 3);
    }

    #[test]
    fn max_wr_bytes_limits_merge() {
        let mut id = 0;
        let lim = BatchLimits {
            max_wr_bytes: 8192,
            ..Default::default()
        };
        let ios: Vec<AppIo> = (0..4).map(|i| wio(i, i * 4096)).collect();
        let (_, st) = plan(BatchMode::BatchOnMr, &lim, ios, &mut id);
        assert_eq!(st.wqes, 2); // 2 pages per WR
    }

    #[test]
    fn empty_plan() {
        let mut id = 0;
        let (chains, st) = plan(
            BatchMode::Hybrid,
            &BatchLimits::default(),
            vec![],
            &mut id,
        );
        assert!(chains.is_empty());
        assert_eq!(st, PlanStats::default());
    }

    /// Satellite: a contiguous run longer than `max_wr_bytes` splits at
    /// the WR byte cap (the cross-MR boundary) into WRs that still cover
    /// every byte exactly once, with no gap and no overlap.
    #[test]
    fn merge_run_splits_exactly_at_wr_byte_cap() {
        let mut id = 0;
        let lim = BatchLimits {
            max_wr_bytes: 8192,
            ..Default::default()
        };
        // 5 contiguous pages -> 2+2+1 pages across three WRs
        let ios: Vec<AppIo> = (0..5).map(|i| wio(i, i * 4096)).collect();
        let (chains, st) = plan(BatchMode::BatchOnMr, &lim, ios, &mut id);
        assert_eq!(st.wqes, 3);
        let mut wrs: Vec<&WorkRequest> = chains.iter().flat_map(|c| c.wrs.iter()).collect();
        wrs.sort_by_key(|w| w.remote_addr);
        let mut cursor = 0u64;
        for w in wrs {
            assert_eq!(w.remote_addr, cursor, "no gap, no overlap at the boundary");
            assert!(w.len <= 8192);
            cursor += w.len;
        }
        assert_eq!(cursor, 5 * 4096, "every byte covered exactly once");
    }

    /// Satellite property: across every mode, the WRs a plan produces
    /// cover exactly the union of the input byte ranges — each input
    /// byte appears in exactly one WR (no loss, no double-count), every
    /// multi-SGE WR is a contiguous run, and runs split at the
    /// `max_wr_bytes` boundary. Inputs are drained through a real
    /// `MergeQueue`, so this is the merge-queue → planner adjacency
    /// contract end to end.
    #[test]
    fn prop_plan_covers_exact_byte_union() {
        use crate::coordinator::merge_queue::{MergeCheck, MergeQueue};
        use std::collections::BTreeMap;
        for mode in [
            BatchMode::Single,
            BatchMode::BatchOnMr,
            BatchMode::Doorbell,
            BatchMode::Hybrid,
        ] {
            prop::forall(cfg(0xC0FE + mode as u64), |rng, size| {
                let lim = BatchLimits {
                    max_sge: 1 + rng.gen_below(8) as usize,
                    max_chain: 1 + rng.gen_below(6) as usize,
                    // small cap so contiguous runs regularly cross it
                    max_wr_bytes: (1 + rng.gen_below(4)) * 4096,
                };
                // distinct pages, dense enough that adjacency is common
                let n = size.min(48);
                let mut pages: Vec<u64> = (0..n as u64 * 2).collect();
                rng.shuffle(&mut pages);
                pages.truncate(n);
                let mut q = MergeQueue::new();
                let mut by_id: BTreeMap<u64, (u64, u64)> = BTreeMap::new();
                let mut want: BTreeMap<u64, u64> = BTreeMap::new();
                for (i, &p) in pages.iter().enumerate() {
                    let req = io(i as u64, 0, p * 4096, 4096, Dir::Write);
                    by_id.insert(req.id, (req.addr, req.len));
                    want.insert(req.addr, req.len);
                    q.push(req);
                }
                let drained = match q.merge_check(u64::MAX) {
                    MergeCheck::Drained(v) => v,
                    other => return Err(format!("drain failed: {other:?}")),
                };
                if drained.len() != n {
                    return Err("merge queue lost requests".into());
                }
                let mut id = 0;
                let (chains, _) = plan(mode, &lim, drained, &mut id);
                let mut covered: BTreeMap<u64, u64> = BTreeMap::new();
                for c in &chains {
                    for w in c.wrs.iter() {
                        let mut ranges: Vec<(u64, u64)> =
                            w.app_ios.iter().map(|i| by_id[i]).collect();
                        ranges.sort_unstable();
                        let mut cursor = w.remote_addr;
                        let mut total = 0u64;
                        for &(a, l) in &ranges {
                            if a != cursor {
                                return Err(format!(
                                    "WR {} not contiguous: io at {a}, cursor {cursor}",
                                    w.wr_id
                                ));
                            }
                            cursor = a + l;
                            total += l;
                            if covered.insert(a, l).is_some() {
                                return Err(format!("byte range at {a} double-counted"));
                            }
                        }
                        if total != w.len {
                            return Err(format!("WR len {} != sum of its ios {total}", w.len));
                        }
                        if w.num_sge > 1 && w.len > lim.max_wr_bytes {
                            return Err(format!(
                                "merged WR of {} bytes crossed the {} MR cap",
                                w.len,
                                lim.max_wr_bytes
                            ));
                        }
                    }
                }
                if covered != want {
                    return Err(format!(
                        "covered union differs from inputs: {} vs {} ranges",
                        covered.len(),
                        want.len()
                    ));
                }
                Ok(())
            });
        }
    }

    /// The arena planner: flat spans tile the WR buffer exactly, agree
    /// with the allocating wrapper, and the reused buffers stop growing
    /// at steady state.
    #[test]
    fn plan_into_spans_tile_the_wr_buffer_and_buffers_stabilize() {
        let lim = BatchLimits::default();
        let mk_ios = || -> Vec<AppIo> {
            let mut v: Vec<AppIo> = (0..12u64).map(|i| wio(i, i * 4096)).collect();
            v.extend((0..4u64).map(|i| io(12 + i, 1, (10 + i * 3) << 20, 4096, Dir::Write)));
            v
        };
        let mut wr_id_a = 0u64;
        let (chains_a, stats_a) = plan(BatchMode::Hybrid, &lim, mk_ios(), &mut wr_id_a);

        let mut ios = mk_ios();
        let mut wrs = Vec::new();
        let mut spans = Vec::new();
        let mut arena = PlanArena::default();
        let mut wr_id_b = 0u64;
        let stats_b = plan_into(
            BatchMode::Hybrid,
            &lim,
            &mut ios,
            &mut wr_id_b,
            &mut wrs,
            &mut spans,
            &mut arena,
        );
        assert!(ios.is_empty(), "inputs drained in place");
        assert_eq!(stats_a, stats_b);
        // spans tile [0, wrs.len()) contiguously, in order
        let mut cursor = 0usize;
        for s in &spans {
            assert_eq!(s.start, cursor, "span gap/overlap at {cursor}");
            assert!(s.end > s.start);
            cursor = s.end;
        }
        assert_eq!(cursor, wrs.len());
        // chain-by-chain agreement with the allocating wrapper
        assert_eq!(chains_a.len(), spans.len());
        for (c, s) in chains_a.iter().zip(spans.iter()) {
            assert_eq!(c.node, s.node);
            assert_eq!(c.wrs.len(), s.end - s.start);
            for (wa, wb) in c.wrs.iter().zip(wrs[s.start..s.end].iter()) {
                assert_eq!(wa.wr_id, wb.wr_id);
                assert_eq!(wa.app_ios, wb.app_ios);
                assert_eq!((wa.len, wa.remote_addr), (wb.len, wb.remote_addr));
            }
        }
        // steady state: reused buffers keep their capacity and stop
        // growing after the first call warmed them
        for _ in 0..50 {
            wrs.clear();
            spans.clear();
            let mut ios = mk_ios();
            let _ = plan_into(
                BatchMode::Hybrid,
                &lim,
                &mut ios,
                &mut wr_id_b,
                &mut wrs,
                &mut spans,
                &mut arena,
            );
        }
        assert!(wrs.capacity() >= wrs.len());
        assert_eq!(arena.active, 2, "two destination nodes grouped");
    }

    /// Property: planning conserves app I/Os (each exactly once), never
    /// exceeds SGE/chain/byte limits, and `wqes`/`posts` counters match the
    /// produced structure, for every mode.
    #[test]
    fn prop_plan_conservation_and_limits() {
        for mode in [
            BatchMode::Single,
            BatchMode::BatchOnMr,
            BatchMode::Doorbell,
            BatchMode::Hybrid,
        ] {
            prop::forall(cfg(0xBA7C4 + mode as u64), |rng, size| {
                let lim = BatchLimits {
                    max_sge: 1 + rng.gen_below(8) as usize,
                    max_chain: 1 + rng.gen_below(8) as usize,
                    max_wr_bytes: (1 + rng.gen_below(64)) * 4096,
                };
                let n = size;
                let ios: Vec<AppIo> = (0..n)
                    .map(|i| {
                        let dir = if rng.gen_bool(0.5) { Dir::Read } else { Dir::Write };
                        // cluster addresses so adjacency actually occurs
                        let addr = rng.gen_below(n as u64 * 2) * 4096;
                        io(i as u64, rng.gen_below(3) as usize, addr, 4096, dir)
                    })
                    .collect();
                let mut id = 0;
                let (chains, st) = plan(mode, &lim, ios.clone(), &mut id);
                let mut seen: Vec<u64> = chains
                    .iter()
                    .flat_map(|c| c.wrs.iter())
                    .flat_map(|w| w.app_ios.iter().copied())
                    .collect();
                seen.sort_unstable();
                let mut want: Vec<u64> = ios.iter().map(|x| x.id).collect();
                want.sort_unstable();
                if seen != want {
                    return Err(format!("io loss/dup: {seen:?} vs {want:?}"));
                }
                let wqes: u64 = chains.iter().map(|c| c.wrs.len() as u64).sum();
                if wqes != st.wqes {
                    return Err(format!("wqe count mismatch {wqes} vs {}", st.wqes));
                }
                if chains.len() as u64 != st.posts {
                    return Err("post count mismatch".into());
                }
                for c in &chains {
                    if c.wrs.len() > lim.max_chain {
                        return Err("chain limit exceeded".into());
                    }
                    for w in &c.wrs {
                        if w.num_sge > lim.max_sge {
                            return Err("sge limit exceeded".into());
                        }
                        if w.num_sge > 1 && w.len > lim.max_wr_bytes {
                            return Err("wr byte limit exceeded".into());
                        }
                        if w.node != c.node {
                            return Err("wr node != chain node".into());
                        }
                    }
                }
                Ok(())
            });
        }
    }
}
