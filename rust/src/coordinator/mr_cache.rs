//! Dynamic MR cache — the pinning-free memory path (ROADMAP item 3).
//!
//! The paper's MR strategy (§5.1, Fig 4) is a *static* per-request
//! decision: preMR staging copies vs dynMR register-per-I/O. Both assume
//! the working set either fits a pre-pinned pool or tolerates a
//! registration on every request. The regime the paper actually targets —
//! working sets far larger than pinnable memory — needs what NP-RDMA and
//! the Psistakis thesis build: registration as a *cache*.
//!
//! [`MrCache`] keeps registered spans (fixed-size address ranges, the
//! granularity one `ibv_reg_mr` call would cover) under a clock
//! (second-chance) policy with a configurable pinned-bytes cap:
//!
//! * **Lazy registration** — the first WR touching a span registers it
//!   (a miss, charged at the fabric's registration cost); subsequent WRs
//!   find it resident (a hit, charged at an lkey-lookup cost).
//! * **Eviction pressure** — when the cap is reached, the clock hand
//!   sweeps for an unreferenced victim, so hot spans survive and cold
//!   ones lose their pin.
//! * **Batched, deferred deregistration** — evicted spans queue for
//!   deregistration instead of paying `ibv_dereg_mr` on the post path;
//!   the engine flushes the queue in batches off the critical path
//!   (after the doorbell chains of a drain cycle are already timed).
//!
//! Everything is sized at construction: the frame array, the span map,
//! and the dereg queue never reallocate in steady state, which is what
//! keeps `engine_pipeline_64ios_steady` at `allocs_per_op == 0` with the
//! cache enabled.

use crate::metrics::MrCacheStats;
use crate::util::fxhash::FxHashMap;

/// Default registration-span granularity: one MR covers this many bytes
/// of remote address space. 16 pages amortizes per-call overhead without
/// pinning much beyond the touched range (NP-RDMA uses the same order).
pub const MR_SPAN_BYTES: u64 = 64 * 1024;

/// Default deferred-deregistration batch: evicted spans accumulate until
/// this many are pending, then one flush deregisters them all.
pub const MR_DEREG_BATCH: usize = 32;

/// Outcome of probing one WR's address range against the cache: how many
/// registration spans were already resident and how many had to be
/// lazily registered. `Copy` — per-request MR state never allocates.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Touch {
    pub hit_spans: u32,
    pub miss_spans: u32,
}

#[derive(Debug, Clone, Copy)]
struct SpanFrame {
    span: u64,
    referenced: bool,
}

/// Clock (second-chance) cache of registered MR spans. See the module
/// docs for the protocol; see [`crate::coordinator::engine::IoEngine`]
/// for where hits/misses are charged and the dereg queue is flushed.
#[derive(Debug)]
pub struct MrCache {
    span_bytes: u64,
    cap_spans: usize,
    frames: Vec<SpanFrame>,
    map: FxHashMap<u64, usize>,
    hand: usize,
    dereg_batch: usize,
    /// Evicted spans awaiting a batched deregistration. Bounded at twice
    /// the batch size: reaching the bound forces an internal flush, so
    /// the queue can never grow (and never reallocates).
    dereg_queue: Vec<u64>,
    pub stats: MrCacheStats,
}

impl MrCache {
    /// Cache with the default span granularity and dereg batch.
    pub fn new(cap_bytes: u64) -> Self {
        Self::with_geometry(cap_bytes, MR_SPAN_BYTES, MR_DEREG_BATCH)
    }

    /// Fully parameterized constructor (experiments sweep span size and
    /// batch depth; the engine uses the defaults).
    pub fn with_geometry(cap_bytes: u64, span_bytes: u64, dereg_batch: usize) -> Self {
        assert!(span_bytes > 0, "span granularity must be positive");
        assert!(
            cap_bytes >= span_bytes,
            "pinned cap {cap_bytes} below one registration span {span_bytes}"
        );
        assert!(dereg_batch > 0);
        let cap_spans = (cap_bytes / span_bytes) as usize;
        let prealloc = cap_spans.min(1 << 20);
        Self {
            span_bytes,
            cap_spans,
            frames: Vec::with_capacity(prealloc),
            map: FxHashMap::with_capacity_and_hasher(prealloc, Default::default()),
            hand: 0,
            dereg_batch,
            dereg_queue: Vec::with_capacity(dereg_batch * 2),
            stats: MrCacheStats {
                cap_bytes,
                ..Default::default()
            },
        }
    }

    pub fn span_bytes(&self) -> u64 {
        self.span_bytes
    }

    pub fn cap_bytes(&self) -> u64 {
        self.stats.cap_bytes
    }

    /// Registered spans currently resident (pinned).
    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn contains_span(&self, span: u64) -> bool {
        self.map.contains_key(&span)
    }

    /// Probe the spans covering `[addr, addr+len)`; lazily register every
    /// non-resident one, evicting under cap pressure. Returns the
    /// hit/miss split so the caller can charge the fabric cost model.
    pub fn touch(&mut self, addr: u64, len: u64) -> Touch {
        debug_assert!(len > 0);
        let first = addr / self.span_bytes;
        let last = (addr + len - 1) / self.span_bytes;
        let mut t = Touch::default();
        for span in first..=last {
            if let Some(&i) = self.map.get(&span) {
                self.frames[i].referenced = true;
                t.hit_spans += 1;
            } else {
                self.register(span);
                t.miss_spans += 1;
            }
        }
        self.stats.mr_hits += u64::from(t.hit_spans);
        self.stats.mr_misses += u64::from(t.miss_spans);
        self.stats.pinned_bytes = self.map.len() as u64 * self.span_bytes;
        t
    }

    /// Lazily register `span`, evicting a victim if the cap is reached.
    fn register(&mut self, span: u64) {
        if self.frames.len() < self.cap_spans {
            self.map.insert(span, self.frames.len());
            self.frames.push(SpanFrame {
                span,
                referenced: true,
            });
            return;
        }
        // clock sweep: clear reference bits until an unreferenced victim
        // turns up (terminates — a full lap clears every bit)
        let slot = loop {
            let f = &mut self.frames[self.hand];
            if f.referenced {
                f.referenced = false;
                self.hand = (self.hand + 1) % self.frames.len();
            } else {
                break self.hand;
            }
        };
        let victim = self.frames[slot].span;
        self.map.remove(&victim);
        self.stats.mr_evictions += 1;
        // deregistration is deferred: queue the victim, force a flush
        // only if the caller never drained (bounded queue, no realloc)
        if self.dereg_queue.len() == self.dereg_queue.capacity() {
            self.flush_deregs();
        }
        self.dereg_queue.push(victim);
        self.frames[slot] = SpanFrame {
            span,
            referenced: true,
        };
        self.map.insert(span, slot);
        self.hand = (self.hand + 1) % self.frames.len();
    }

    /// Evicted spans awaiting deregistration.
    pub fn pending_deregs(&self) -> usize {
        self.dereg_queue.len()
    }

    /// Batch threshold at which the engine flushes.
    pub fn dereg_batch(&self) -> usize {
        self.dereg_batch
    }

    /// Deregister every pending span in one batch; returns how many were
    /// deregistered (0 if the queue was empty — not counted as a batch).
    pub fn flush_deregs(&mut self) -> usize {
        let n = self.dereg_queue.len();
        if n > 0 {
            self.dereg_queue.clear();
            self.stats.mr_dereg_batches += 1;
        }
        n
    }

    /// Cumulative counters plus the current pinned/cap occupancy.
    pub fn snapshot(&self) -> MrCacheStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{self, cfg};

    fn spans(c: &MrCache) -> u64 {
        c.span_bytes()
    }

    #[test]
    fn first_touch_registers_then_hits() {
        let mut c = MrCache::new(4 * MR_SPAN_BYTES);
        let t = c.touch(0, 4096);
        assert_eq!(
            t,
            Touch {
                hit_spans: 0,
                miss_spans: 1
            }
        );
        // any address within the span is now a hit
        let t = c.touch(MR_SPAN_BYTES - 4096, 4096);
        assert_eq!(
            t,
            Touch {
                hit_spans: 1,
                miss_spans: 0
            }
        );
        assert_eq!(c.stats.mr_hits, 1);
        assert_eq!(c.stats.mr_misses, 1);
        assert_eq!(c.stats.pinned_bytes, MR_SPAN_BYTES);
    }

    #[test]
    fn wr_straddling_spans_counts_each_span() {
        let mut c = MrCache::new(8 * MR_SPAN_BYTES);
        // 3 spans: last page of span 0 through first page of span 2
        let t = c.touch(MR_SPAN_BYTES - 4096, MR_SPAN_BYTES + 8192);
        assert_eq!(t.miss_spans, 3);
        assert_eq!(c.len(), 3);
        let t = c.touch(MR_SPAN_BYTES - 4096, MR_SPAN_BYTES + 8192);
        assert_eq!(t.hit_spans, 3);
    }

    #[test]
    fn eviction_under_cap_pressure_is_counted_and_deferred() {
        let mut c = MrCache::with_geometry(2 * MR_SPAN_BYTES, MR_SPAN_BYTES, 4);
        c.touch(0, 4096); // span 0
        c.touch(spans(&c), 4096); // span 1 — at cap
        assert_eq!(c.stats.mr_evictions, 0);
        c.touch(2 * spans(&c), 4096); // span 2 evicts one victim
        assert_eq!(c.stats.mr_evictions, 1);
        assert_eq!(c.len(), 2, "pinned spans never exceed the cap");
        assert!(c.stats.pinned_bytes <= c.cap_bytes());
        assert_eq!(c.pending_deregs(), 1, "dereg deferred, not immediate");
        assert_eq!(c.stats.mr_dereg_batches, 0);
        assert_eq!(c.flush_deregs(), 1);
        assert_eq!(c.stats.mr_dereg_batches, 1);
        assert_eq!(c.pending_deregs(), 0);
        assert_eq!(c.flush_deregs(), 0, "empty flush is not a batch");
        assert_eq!(c.stats.mr_dereg_batches, 1);
    }

    #[test]
    fn second_chance_evicts_the_unreferenced_span() {
        let s = MR_SPAN_BYTES;
        let mut c = MrCache::with_geometry(2 * s, s, 4);
        c.touch(0, 4096); // span 0
        c.touch(s, 4096); // span 1
        // both referenced: the sweep clears both bits, wraps, and takes
        // span 0 (first past the hand)
        c.touch(2 * s, 4096); // span 2 evicts span 0
        assert!(!c.contains_span(0) && c.contains_span(1));
        // span 1 survived with its bit cleared; span 2 is freshly
        // referenced — the next fault must take 1 and spare 2
        c.touch(3 * s, 4096); // span 3
        assert!(c.contains_span(2), "referenced span kept its second chance");
        assert!(!c.contains_span(1), "unreferenced span was the victim");
        assert_eq!(c.stats.mr_evictions, 2);
    }

    #[test]
    fn overfull_dereg_queue_self_flushes_and_never_grows() {
        let mut c = MrCache::with_geometry(MR_SPAN_BYTES, MR_SPAN_BYTES, 2);
        let bound = c.dereg_queue.capacity();
        assert!(bound >= 4, "queue bound is twice the batch");
        // single-frame cache: every new span evicts — never flushed by
        // the caller, the queue must flush itself at its bound
        for i in 0..64u64 {
            c.touch(i * spans(&c), 4096);
        }
        assert!(c.pending_deregs() <= bound);
        assert_eq!(c.dereg_queue.capacity(), bound, "no reallocation");
        assert!(c.stats.mr_dereg_batches >= 1, "forced flushes counted");
        assert_eq!(c.stats.mr_evictions, 63);
    }

    #[test]
    fn snapshot_tracks_occupancy_and_hit_rate() {
        let mut c = MrCache::new(4 * MR_SPAN_BYTES);
        c.touch(0, 2 * MR_SPAN_BYTES); // 2 misses
        c.touch(0, 2 * MR_SPAN_BYTES); // 2 hits
        let s = c.snapshot();
        assert_eq!(s.mr_hits, 2);
        assert_eq!(s.mr_misses, 2);
        assert!((s.hit_rate() - 0.5).abs() < 1e-9);
        assert_eq!(s.pinned_bytes, 2 * MR_SPAN_BYTES);
        assert_eq!(s.cap_bytes, 4 * MR_SPAN_BYTES);
    }

    /// Property: the map and frame array stay consistent, residency never
    /// exceeds the cap, a hit is only reported for a resident span, and
    /// the dereg queue stays within its preallocated bound.
    #[test]
    fn prop_mr_cache_invariants() {
        prop::forall(cfg(0x3ECAC4E), |rng, size| {
            let cap_spans = 1 + rng.gen_below(8);
            let batch = 1 + rng.gen_below(6) as usize;
            let mut c = MrCache::with_geometry(cap_spans * MR_SPAN_BYTES, MR_SPAN_BYTES, batch);
            let bound = c.dereg_queue.capacity();
            for _ in 0..size * 8 {
                let span = rng.gen_below(24);
                let was_resident = c.contains_span(span);
                let len = 1 + rng.gen_below(MR_SPAN_BYTES);
                let t = c.touch(span * MR_SPAN_BYTES, len);
                if was_resident && t.hit_spans != 1 {
                    return Err("resident span did not hit".into());
                }
                if !was_resident && t.miss_spans != 1 {
                    return Err("absent span did not miss".into());
                }
                if c.len() > cap_spans as usize {
                    return Err(format!("over cap: {} > {cap_spans}", c.len()));
                }
                if c.stats.pinned_bytes != c.len() as u64 * MR_SPAN_BYTES {
                    return Err("pinned_bytes drifted from residency".into());
                }
                if c.pending_deregs() > bound {
                    return Err("dereg queue exceeded its bound".into());
                }
                if rng.gen_bool(0.1) {
                    c.flush_deregs();
                }
                // every mapped span points at a frame holding it
                for (&s, &i) in c.map.iter() {
                    if c.frames[i].span != s {
                        return Err("map/frames disagree".into());
                    }
                }
            }
            Ok(())
        });
    }
}
