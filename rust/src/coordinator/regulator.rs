//! RDMA-I/O-level admission control (paper §5.1, Fig 8).
//!
//! A window-based in-flight-bytes limiter implemented *on* the merge queue
//! — no extra queue layer. While the window is closed, requests wait in the
//! merge queue, which turns the forced wait into extra merge opportunities.
//! The policy is pluggable (the paper's software hook for congestion
//! control); the prototype uses a static window sized to the NIC's
//! capability (the paper measures ~7 MB in-flight at the knee).
//!
//! **Multi-tenant QoS** makes the window hierarchical: the global policy
//! window splits into weighted per-tenant sub-windows
//! (`share_t = window × w_t / Σw`). The split is *soft* — the merge
//! queue's DRR drain serves entitled demand first, then lets any tenant
//! borrow whatever budget entitled demand left unclaimed (work-conserving:
//! an idle tenant's quota is never wasted). The regulator tracks each
//! tenant's in-flight occupancy, posted/retired bytes, and borrow events
//! (a post that pushes a tenant past its share), and hands the drain path
//! per-tenant entitlements (`share_t − in_flight_t`). With one tenant the
//! share *is* the window and everything behaves exactly as before.

use crate::fabric::TenantId;
use crate::util::stats::Ewma;

/// Pluggable admission policy: returns the current window in bytes.
pub trait AdmissionPolicy: std::fmt::Debug + Send {
    fn window_bytes(&mut self, now_ns: u64, feedback: &Feedback) -> u64;
    fn name(&self) -> &'static str;
}

/// Feedback the regulator exposes to policies (completion latency EWMA and
/// in-flight level) — enough to implement Timely/HPCC-style controllers via
/// the hook, as the paper suggests.
#[derive(Debug, Default, Clone)]
pub struct Feedback {
    pub in_flight_bytes: u64,
    pub last_completion_ns: u64,
    pub rtt_ewma_ns: f64,
}

/// The paper's prototype policy: a static window set at init time.
#[derive(Debug, Clone)]
pub struct StaticWindow(pub u64);

impl AdmissionPolicy for StaticWindow {
    fn window_bytes(&mut self, _now: u64, _fb: &Feedback) -> u64 {
        self.0
    }
    fn name(&self) -> &'static str {
        "static"
    }
}

/// No admission control (the Fig 1 / "without AC" configurations).
#[derive(Debug, Clone)]
pub struct Unlimited;

impl AdmissionPolicy for Unlimited {
    fn window_bytes(&mut self, _now: u64, _fb: &Feedback) -> u64 {
        u64::MAX
    }
    fn name(&self) -> &'static str {
        "unlimited"
    }
}

/// Extension (the paper's "hook to implement custom admission control"):
/// an AIMD controller on completion RTT — grow the window additively while
/// RTT stays below a target, halve it when RTT exceeds the target. Uses
/// integer-friendly math (the paper notes kernel space cannot afford
/// gradient floating-point à la Timely; EWMA + compare is cheap).
#[derive(Debug)]
pub struct AimdWindow {
    window: u64,
    min: u64,
    max: u64,
    add_step: u64,
    target_rtt_ns: u64,
    rtt: Ewma,
    last_decrease_ns: u64,
    cooldown_ns: u64,
}

impl AimdWindow {
    pub fn new(initial: u64, min: u64, max: u64, target_rtt_ns: u64) -> Self {
        Self {
            window: initial,
            min,
            max,
            add_step: 64 * 1024,
            target_rtt_ns,
            rtt: Ewma::new(0.2),
            last_decrease_ns: 0,
            cooldown_ns: 200_000,
        }
    }
}

impl AdmissionPolicy for AimdWindow {
    fn window_bytes(&mut self, now: u64, fb: &Feedback) -> u64 {
        if fb.last_completion_ns > 0 {
            let rtt = self.rtt.update(fb.last_completion_ns as f64);
            if rtt > self.target_rtt_ns as f64 {
                if now.saturating_sub(self.last_decrease_ns) > self.cooldown_ns {
                    self.window = (self.window / 2).max(self.min);
                    self.last_decrease_ns = now;
                }
            } else {
                self.window = (self.window + self.add_step).min(self.max);
            }
        }
        self.window
    }
    fn name(&self) -> &'static str {
        "aimd"
    }
}

/// Per-tenant accounting inside the regulator: the tenant's weight, its
/// slice of the in-flight window, and cumulative QoS counters. Lives in a
/// plain `Vec` indexed by the dense [`TenantId`] (sized once at build, so
/// the hot path never allocates).
#[derive(Debug, Clone, Default)]
pub struct TenantLedger {
    /// DRR / sub-window weight.
    pub weight: u64,
    /// Bytes this tenant currently has in flight.
    pub in_flight: u64,
    /// High-water mark of `in_flight`.
    pub peak_in_flight: u64,
    /// Cumulative bytes posted.
    pub posted_bytes: u64,
    /// Cumulative bytes retired (completions, success or error).
    pub retired_bytes: u64,
    /// Posts that pushed this tenant past its weighted share — i.e. quota
    /// borrowed from tenants that were not using theirs.
    pub borrow_events: u64,
    /// WRs admitted for this tenant.
    pub admitted: u64,
}

/// The regulator: tracks in-flight bytes against the policy window,
/// globally and per tenant.
///
/// Posting and completion are keyed by `wr_id`: the regulator keeps a
/// per-WR ledger (bytes *and* tenant) and checks that every completion
/// releases exactly the bytes its post reserved, against the same
/// tenant. An error completion that released the wrong amount (or a
/// duplicate completion that released twice, or a completion billed to
/// the wrong tenant) would strand window capacity forever — the leak is
/// invisible in steady state and fatal under load. Debug builds panic at
/// the offending call; release builds count the violation in
/// [`Regulator::window_leaks`], which the chaos quiescence invariants
/// gate at zero (so a leak fails the sweep in release too, with the
/// seed to replay it).
#[derive(Debug)]
pub struct Regulator {
    policy: Box<dyn AdmissionPolicy>,
    in_flight: u64,
    feedback: Feedback,
    /// Per-tenant ledgers, indexed by dense tenant id. Always at least
    /// one entry (tenant 0), so single-tenant accounting needs no branch.
    tenants: Vec<TenantLedger>,
    total_weight: u64,
    /// Window from the most recent `available()` call — shares and
    /// entitlements are computed against it without re-querying the
    /// policy (policies may be stateful in time).
    cur_window: u64,
    pub admitted: u64,
    pub blocked_checks: u64,
    pub peak_in_flight: u64,
    /// Ledger violations observed (double post, unmatched or mismatched
    /// release). Always 0 on a healthy engine; the hash map it is
    /// checked against reaches steady capacity during warm-up, so the
    /// always-on bookkeeping costs the hot path no allocations.
    pub window_leaks: u64,
    /// Per-WR ledger: wr_id -> (bytes, tenant) reserved at post time.
    ledger: crate::util::fxhash::FxHashMap<u64, (u64, TenantId)>,
}

impl Default for Regulator {
    fn default() -> Self {
        Self::unlimited()
    }
}

impl Regulator {
    pub fn new(policy: Box<dyn AdmissionPolicy>) -> Self {
        Self {
            policy,
            in_flight: 0,
            feedback: Feedback::default(),
            tenants: vec![TenantLedger {
                weight: 1,
                ..TenantLedger::default()
            }],
            total_weight: 1,
            cur_window: u64::MAX,
            admitted: 0,
            blocked_checks: 0,
            peak_in_flight: 0,
            window_leaks: 0,
            ledger: crate::util::fxhash::FxHashMap::default(),
        }
    }

    pub fn unlimited() -> Self {
        Self::new(Box::new(Unlimited))
    }

    pub fn static_window(bytes: u64) -> Self {
        Self::new(Box::new(StaticWindow(bytes)))
    }

    /// Split the window into weighted per-tenant sub-windows — one weight
    /// per tenant, tenant ids dense from 0. Consuming builder, meant for
    /// engine construction time (before any traffic).
    pub fn with_tenants(mut self, weights: &[u64]) -> Self {
        self.set_tenants(weights);
        self
    }

    /// Non-consuming form of [`Regulator::with_tenants`].
    pub fn set_tenants(&mut self, weights: &[u64]) {
        assert!(!weights.is_empty(), "at least one tenant");
        assert!(
            weights.iter().all(|&w| (1..=1 << 20).contains(&w)),
            "tenant weights must be in 1..=2^20"
        );
        assert_eq!(self.in_flight, 0, "set_tenants on a live regulator");
        self.tenants = weights
            .iter()
            .map(|&w| TenantLedger {
                weight: w,
                ..TenantLedger::default()
            })
            .collect();
        self.total_weight = weights.iter().sum();
    }

    /// Number of configured tenants (1 unless [`Regulator::with_tenants`]).
    pub fn tenant_count(&self) -> usize {
        self.tenants.len()
    }

    /// The ledger for `tenant` (panics on an out-of-range id).
    pub fn tenant(&self, tenant: TenantId) -> &TenantLedger {
        &self.tenants[tenant]
    }

    pub fn in_flight(&self) -> u64 {
        self.in_flight
    }

    /// Swap the admission policy **without** touching the in-flight
    /// accounting or the debug byte ledger: WRs posted under the old
    /// policy still release exactly their reserved bytes. This is what
    /// makes mid-run admission churn (a live window re-size) safe — a
    /// shrink below the current in-flight level simply blocks new
    /// admissions until completions drain it below the new window.
    pub fn set_policy(&mut self, policy: Box<dyn AdmissionPolicy>) {
        self.policy = policy;
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    /// Bytes that may still be admitted right now (merge-queue drains pass
    /// this as the window argument so a closed window leaves requests
    /// queued — where they can still merge). Also refreshes the cached
    /// window that shares/entitlements are computed against.
    pub fn available(&mut self, now_ns: u64) -> u64 {
        let w = self.policy.window_bytes(now_ns, &self.feedback);
        self.cur_window = w;
        let avail = w.saturating_sub(self.in_flight);
        if avail == 0 {
            self.blocked_checks += 1;
        }
        avail
    }

    /// Tenant `t`'s weighted share of the current window
    /// (`window × w_t / Σw`, in bytes). An unlimited window stays
    /// unlimited for every tenant.
    pub fn share(&self, tenant: TenantId) -> u64 {
        if self.cur_window == u64::MAX || self.tenants.len() <= 1 {
            return self.cur_window;
        }
        let w = self.tenants[tenant].weight as u128;
        ((self.cur_window as u128 * w) / self.total_weight as u128) as u64
    }

    /// Bytes tenant `t` may still admit inside its own sub-window
    /// (`share_t − in_flight_t`, floored at 0). The DRR drain honors this
    /// in its entitled phase; its borrow phase may exceed it when other
    /// tenants leave budget unclaimed.
    pub fn entitlement(&self, tenant: TenantId) -> u64 {
        self.share(tenant)
            .saturating_sub(self.tenants[tenant].in_flight)
    }

    /// Fill `out` with every tenant's entitlement (reused scratch — the
    /// engine's per-drain call allocates nothing in steady state).
    pub fn entitlements_into(&self, out: &mut Vec<u64>) {
        out.clear();
        for t in 0..self.tenants.len() {
            out.push(self.entitlement(t));
        }
    }

    /// Record that WR `wr_id` of `tenant` reserved `bytes` of the window.
    pub fn on_post(&mut self, wr_id: u64, tenant: TenantId, bytes: u64) {
        let prev = self.ledger.insert(wr_id, (bytes, tenant));
        if prev.is_some() {
            self.window_leaks += 1;
            debug_assert!(false, "wr_id {wr_id} posted twice without completing");
        }
        self.in_flight += bytes;
        self.feedback.in_flight_bytes = self.in_flight;
        self.peak_in_flight = self.peak_in_flight.max(self.in_flight);
        self.admitted += 1;
        let share = self.share(tenant);
        let led = &mut self.tenants[tenant];
        led.in_flight += bytes;
        led.peak_in_flight = led.peak_in_flight.max(led.in_flight);
        led.posted_bytes += bytes;
        led.admitted += 1;
        if led.in_flight > share {
            // this post runs on quota another tenant is not using
            led.borrow_events += 1;
        }
    }

    /// Record a completion (success *or* error — either way the WR left
    /// the NIC): releases window (global and per-tenant) and feeds RTT to
    /// the policy. Checks `bytes` and `tenant` against what `wr_id`'s
    /// post reserved so a mismatched release cannot silently strand
    /// window capacity — debug builds panic, release builds count a
    /// [`Regulator::window_leaks`] violation.
    pub fn on_complete(&mut self, wr_id: u64, tenant: TenantId, bytes: u64, rtt_ns: u64) {
        match self.ledger.remove(&wr_id) {
            Some((posted, posted_tenant)) => {
                if posted != bytes {
                    self.window_leaks += 1;
                    debug_assert_eq!(
                        posted,
                        bytes,
                        "wr_id {wr_id} completed {bytes} bytes but posted {posted}"
                    );
                }
                if posted_tenant != tenant {
                    self.window_leaks += 1;
                    debug_assert_eq!(
                        posted_tenant,
                        tenant,
                        "wr_id {wr_id} completed by tenant {tenant} but posted by tenant {posted_tenant}"
                    );
                }
            }
            None => {
                self.window_leaks += 1;
                #[cfg(debug_assertions)]
                panic!("wr_id {wr_id} completed without a matching post");
            }
        }
        debug_assert!(self.in_flight >= bytes, "window release underflow");
        self.in_flight = self.in_flight.saturating_sub(bytes);
        self.feedback.in_flight_bytes = self.in_flight;
        self.feedback.last_completion_ns = rtt_ns;
        self.feedback.rtt_ewma_ns = rtt_ns as f64;
        let led = &mut self.tenants[tenant];
        debug_assert!(led.in_flight >= bytes, "tenant window release underflow");
        led.in_flight = led.in_flight.saturating_sub(bytes);
        led.retired_bytes += bytes;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{self, cfg};

    #[test]
    fn unlimited_never_blocks() {
        let mut r = Regulator::unlimited();
        r.on_post(1, 0, u32::MAX as u64);
        assert_eq!(r.available(0), u64::MAX - u32::MAX as u64);
    }

    #[test]
    fn static_window_enforced() {
        let mut r = Regulator::static_window(7 << 20);
        assert_eq!(r.available(0), 7 << 20);
        r.on_post(1, 0, 6 << 20);
        assert_eq!(r.available(0), 1 << 20);
        r.on_post(2, 0, 1 << 20);
        assert_eq!(r.available(0), 0);
        assert_eq!(r.blocked_checks, 1);
        r.on_complete(2, 0, 1 << 20, 10_000);
        assert_eq!(r.available(0), 1 << 20);
    }

    #[test]
    fn peak_tracking() {
        let mut r = Regulator::static_window(10 << 20);
        r.on_post(1, 0, 4 << 20);
        r.on_post(2, 0, 2 << 20);
        r.on_complete(1, 0, 4 << 20, 5_000);
        r.on_post(3, 0, 1 << 20);
        assert_eq!(r.peak_in_flight, 6 << 20);
        assert_eq!(r.in_flight(), 3 << 20);
    }

    /// Satellite: error completions release exactly what their post
    /// reserved — the ledger keeps the window balanced even when every
    /// completion is an error.
    #[test]
    fn error_completions_release_exactly_posted_bytes() {
        let mut r = Regulator::static_window(1 << 20);
        for wr in 0..32u64 {
            r.on_post(wr, 0, 4096);
        }
        assert_eq!(r.in_flight(), 32 * 4096);
        for wr in 0..32u64 {
            // status does not matter to the regulator: the WR left the NIC
            r.on_complete(wr, 0, 4096, 1_000);
        }
        assert_eq!(r.in_flight(), 0, "no stranded window capacity");
        assert_eq!(r.available(0), 1 << 20);
    }

    /// The always-on ledger stat: a healthy post/complete history keeps
    /// `window_leaks` at exactly zero (this is the counter the chaos
    /// quiescence invariants gate in release builds, where the ledger
    /// counts instead of panicking).
    #[test]
    fn healthy_history_counts_zero_window_leaks() {
        let mut r = Regulator::static_window(1 << 20).with_tenants(&[2, 1]);
        for wr in 0..64u64 {
            r.on_post(wr, (wr % 2) as usize, 4096);
        }
        for wr in (0..64u64).rev() {
            r.on_complete(wr, (wr % 2) as usize, 4096, 1_000);
        }
        assert_eq!(r.window_leaks, 0);
        assert_eq!(r.in_flight(), 0);
    }

    /// Release builds must *count* ledger violations instead of
    /// panicking — the same three classes the debug assertions catch.
    #[test]
    #[cfg(not(debug_assertions))]
    fn release_builds_count_ledger_violations() {
        let mut r = Regulator::static_window(1 << 20);
        r.on_post(7, 0, 4096);
        r.on_post(7, 0, 4096); // double post
        r.on_complete(7, 0, 8192, 1_000); // mismatched bytes
        r.on_complete(9, 0, 4096, 1_000); // unmatched release
        assert_eq!(r.window_leaks, 3);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "completed 8192 bytes but posted 4096")]
    fn ledger_catches_mismatched_release() {
        let mut r = Regulator::static_window(1 << 20);
        r.on_post(7, 0, 4096);
        r.on_complete(7, 0, 8192, 1_000);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "completed without a matching post")]
    fn ledger_catches_unposted_completion() {
        let mut r = Regulator::static_window(1 << 20);
        r.on_post(7, 0, 4096);
        r.on_complete(8, 0, 4096, 1_000);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "posted twice")]
    fn ledger_catches_double_post() {
        let mut r = Regulator::static_window(1 << 20);
        r.on_post(7, 0, 4096);
        r.on_post(7, 0, 4096);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "completed by tenant 1 but posted by tenant 0")]
    fn ledger_catches_wrong_tenant_release() {
        let mut r = Regulator::static_window(1 << 20).with_tenants(&[1, 1]);
        r.on_post(7, 0, 4096);
        r.on_complete(7, 1, 4096, 1_000);
    }

    #[test]
    fn aimd_grows_under_target_and_halves_over() {
        let mut p = AimdWindow::new(1 << 20, 256 << 10, 16 << 20, 50_000);
        let fb_fast = Feedback {
            last_completion_ns: 10_000,
            ..Default::default()
        };
        let w0 = p.window_bytes(0, &fb_fast);
        let mut w = w0;
        for t in 1..50u64 {
            w = p.window_bytes(t * 1000, &fb_fast);
        }
        assert!(w > w0, "should grow: {w0} -> {w}");
        // now saturate RTT far above target -> multiplicative decrease
        let fb_slow = Feedback {
            last_completion_ns: 5_000_000,
            ..Default::default()
        };
        let mut w2 = w;
        for t in 50..80u64 {
            w2 = p.window_bytes(t * 1_000_000, &fb_slow);
        }
        assert!(w2 < w / 2 + 1, "should shrink: {w} -> {w2}");
        assert!(w2 >= 256 << 10, "respects floor");
    }

    #[test]
    fn aimd_respects_max() {
        let mut p = AimdWindow::new(15 << 20, 1 << 20, 16 << 20, 1_000_000);
        let fb = Feedback {
            last_completion_ns: 1,
            ..Default::default()
        };
        let mut w = 0;
        for t in 0..100u64 {
            w = p.window_bytes(t, &fb);
        }
        assert_eq!(w, 16 << 20);
    }

    /// Mid-run policy churn keeps the ledger: bytes posted under the old
    /// window release under the new one, and a shrink below the current
    /// in-flight level blocks without stranding capacity.
    #[test]
    fn set_policy_preserves_inflight_accounting() {
        let mut r = Regulator::static_window(8 * 4096);
        r.on_post(1, 0, 6 * 4096);
        r.set_policy(Box::new(StaticWindow(2 * 4096)));
        assert_eq!(r.available(0), 0, "shrunk window blocks new admissions");
        assert_eq!(r.in_flight(), 6 * 4096);
        r.on_complete(1, 0, 6 * 4096, 1_000);
        assert_eq!(r.in_flight(), 0, "old-policy bytes release cleanly");
        assert_eq!(r.available(0), 2 * 4096);
        r.set_policy(Box::new(Unlimited));
        assert_eq!(r.policy_name(), "unlimited");
        assert_eq!(r.available(0), u64::MAX);
    }

    /// Property: in-flight accounting never goes negative and equals
    /// posted-minus-completed at every step.
    #[test]
    fn prop_inflight_accounting() {
        prop::forall(cfg(0xAD0_11), |rng, size| {
            let mut r = Regulator::static_window((1 + rng.gen_below(64)) << 20);
            let mut outstanding: Vec<(u64, u64)> = Vec::new();
            let mut posted: u64 = 0;
            let mut completed: u64 = 0;
            let mut next_wr = 0u64;
            for _ in 0..size * 4 {
                if rng.gen_bool(0.6) || outstanding.is_empty() {
                    let avail = r.available(0);
                    if avail == 0 {
                        continue;
                    }
                    let bytes = (1 + rng.gen_below(32)) * 4096;
                    if bytes > avail {
                        continue;
                    }
                    r.on_post(next_wr, 0, bytes);
                    posted += bytes;
                    outstanding.push((next_wr, bytes));
                    next_wr += 1;
                } else {
                    let i = rng.gen_below(outstanding.len() as u64) as usize;
                    let (wr, bytes) = outstanding.swap_remove(i);
                    r.on_complete(wr, 0, bytes, 1000);
                    completed += bytes;
                }
                if r.in_flight() != posted - completed {
                    return Err(format!(
                        "in_flight {} != posted-completed {}",
                        r.in_flight(),
                        posted - completed
                    ));
                }
            }
            Ok(())
        });
    }

    // ---------------- hierarchical (multi-tenant) suite ----------------

    /// Weighted shares partition the window: each share is proportional
    /// and the shares never sum past the global window.
    #[test]
    fn tenant_shares_partition_the_window() {
        let mut r = Regulator::static_window(8 << 20).with_tenants(&[3, 1]);
        assert_eq!(r.available(0), 8 << 20); // caches the window
        assert_eq!(r.share(0), 6 << 20);
        assert_eq!(r.share(1), 2 << 20);
        assert!(r.share(0) + r.share(1) <= 8 << 20);
        // entitlement shrinks with the tenant's own in-flight only
        r.on_post(1, 0, 5 << 20);
        assert_eq!(r.entitlement(0), 1 << 20);
        assert_eq!(r.entitlement(1), 2 << 20, "peer unaffected");
        let mut ents = Vec::new();
        r.entitlements_into(&mut ents);
        assert_eq!(ents, vec![1 << 20, 2 << 20]);
    }

    /// An unlimited window stays unlimited for every tenant.
    #[test]
    fn unlimited_window_is_unlimited_per_tenant() {
        let mut r = Regulator::unlimited().with_tenants(&[1, 7]);
        assert_eq!(r.available(0), u64::MAX);
        assert_eq!(r.entitlement(0), u64::MAX);
        assert_eq!(r.entitlement(1), u64::MAX);
    }

    /// Borrowed quota is returned on completion: a post past the tenant's
    /// share counts a borrow event, and completing it restores the full
    /// entitlement (nothing stranded in either the global or the
    /// per-tenant ledger).
    #[test]
    fn borrowed_quota_is_returned_on_completion() {
        let mut r = Regulator::static_window(4 * 4096).with_tenants(&[1, 1]);
        assert_eq!(r.available(0), 4 * 4096);
        assert_eq!(r.share(0), 2 * 4096);
        // tenant 0 posts past its share (tenant 1 idle -> DRR borrow)
        r.on_post(1, 0, 3 * 4096);
        assert_eq!(r.tenant(0).borrow_events, 1);
        assert_eq!(r.tenant(0).in_flight, 3 * 4096);
        assert_eq!(r.entitlement(0), 0);
        assert_eq!(r.available(0), 4096, "global window sees the borrow");
        r.on_complete(1, 0, 3 * 4096, 1_000);
        assert_eq!(r.tenant(0).in_flight, 0, "borrowed quota returned");
        assert_eq!(r.entitlement(0), 2 * 4096);
        assert_eq!(r.available(0), 4 * 4096);
        // a post inside the share is not a borrow
        r.on_post(2, 1, 4096);
        assert_eq!(r.tenant(1).borrow_events, 0);
    }

    /// Per-tenant cumulative counters: posted/retired bytes and peaks.
    #[test]
    fn tenant_counters_accumulate() {
        let mut r = Regulator::unlimited().with_tenants(&[1, 2]);
        let _ = r.available(0);
        r.on_post(1, 0, 4096);
        r.on_post(2, 1, 8192);
        r.on_post(3, 1, 4096);
        r.on_complete(2, 1, 8192, 1_000);
        assert_eq!(r.tenant(0).posted_bytes, 4096);
        assert_eq!(r.tenant(0).retired_bytes, 0);
        assert_eq!(r.tenant(1).posted_bytes, 12288);
        assert_eq!(r.tenant(1).retired_bytes, 8192);
        assert_eq!(r.tenant(1).in_flight, 4096);
        assert_eq!(r.tenant(1).peak_in_flight, 12288);
        assert_eq!(r.tenant(1).admitted, 2);
        assert_eq!(r.admitted, 3);
    }

    /// Property: for any weights and window, the weighted sub-windows
    /// never exceed the global window (Σ share_t ≤ window, each
    /// entitlement ≤ its share), and per-tenant in-flight sums to the
    /// global in-flight at every step.
    #[test]
    fn prop_subwindows_never_exceed_global() {
        prop::forall(cfg(0xAD0_22), |rng, size| {
            let lanes = 1 + rng.gen_below(4) as usize;
            let weights: Vec<u64> = (0..lanes).map(|_| 1 + rng.gen_below(8)).collect();
            let window = (1 + rng.gen_below(64)) << 16;
            let mut r = Regulator::static_window(window).with_tenants(&weights);
            let mut outstanding: Vec<(u64, TenantId, u64)> = Vec::new();
            let mut next_wr = 0u64;
            for _ in 0..size * 4 {
                let avail = r.available(0);
                if (rng.gen_bool(0.6) || outstanding.is_empty()) && avail > 0 {
                    let t = rng.gen_below(lanes as u64) as usize;
                    let bytes = (1 + rng.gen_below(8)) * 4096;
                    if bytes > avail {
                        continue;
                    }
                    r.on_post(next_wr, t, bytes);
                    outstanding.push((next_wr, t, bytes));
                    next_wr += 1;
                } else if !outstanding.is_empty() {
                    let i = rng.gen_below(outstanding.len() as u64) as usize;
                    let (wr, t, bytes) = outstanding.swap_remove(i);
                    r.on_complete(wr, t, bytes, 1_000);
                }
                let share_sum: u64 = (0..lanes).map(|t| r.share(t)).sum();
                if share_sum > window {
                    return Err(format!("Σ shares {share_sum} > window {window}"));
                }
                for t in 0..lanes {
                    if r.entitlement(t) > r.share(t) {
                        return Err(format!(
                            "tenant {t} entitlement {} > share {}",
                            r.entitlement(t),
                            r.share(t)
                        ));
                    }
                }
                let tin: u64 = (0..lanes).map(|t| r.tenant(t).in_flight).sum();
                if tin != r.in_flight() {
                    return Err(format!(
                        "per-tenant in-flight {tin} != global {}",
                        r.in_flight()
                    ));
                }
            }
            Ok(())
        });
    }
}
