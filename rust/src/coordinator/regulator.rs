//! RDMA-I/O-level admission control (paper §5.1, Fig 8).
//!
//! A window-based in-flight-bytes limiter implemented *on* the merge queue
//! — no extra queue layer. While the window is closed, requests wait in the
//! merge queue, which turns the forced wait into extra merge opportunities.
//! The policy is pluggable (the paper's software hook for congestion
//! control); the prototype uses a static window sized to the NIC's
//! capability (the paper measures ~7 MB in-flight at the knee).

use crate::util::stats::Ewma;

/// Pluggable admission policy: returns the current window in bytes.
pub trait AdmissionPolicy: std::fmt::Debug + Send {
    fn window_bytes(&mut self, now_ns: u64, feedback: &Feedback) -> u64;
    fn name(&self) -> &'static str;
}

/// Feedback the regulator exposes to policies (completion latency EWMA and
/// in-flight level) — enough to implement Timely/HPCC-style controllers via
/// the hook, as the paper suggests.
#[derive(Debug, Default, Clone)]
pub struct Feedback {
    pub in_flight_bytes: u64,
    pub last_completion_ns: u64,
    pub rtt_ewma_ns: f64,
}

/// The paper's prototype policy: a static window set at init time.
#[derive(Debug, Clone)]
pub struct StaticWindow(pub u64);

impl AdmissionPolicy for StaticWindow {
    fn window_bytes(&mut self, _now: u64, _fb: &Feedback) -> u64 {
        self.0
    }
    fn name(&self) -> &'static str {
        "static"
    }
}

/// No admission control (the Fig 1 / "without AC" configurations).
#[derive(Debug, Clone)]
pub struct Unlimited;

impl AdmissionPolicy for Unlimited {
    fn window_bytes(&mut self, _now: u64, _fb: &Feedback) -> u64 {
        u64::MAX
    }
    fn name(&self) -> &'static str {
        "unlimited"
    }
}

/// Extension (the paper's "hook to implement custom admission control"):
/// an AIMD controller on completion RTT — grow the window additively while
/// RTT stays below a target, halve it when RTT exceeds the target. Uses
/// integer-friendly math (the paper notes kernel space cannot afford
/// gradient floating-point à la Timely; EWMA + compare is cheap).
#[derive(Debug)]
pub struct AimdWindow {
    window: u64,
    min: u64,
    max: u64,
    add_step: u64,
    target_rtt_ns: u64,
    rtt: Ewma,
    last_decrease_ns: u64,
    cooldown_ns: u64,
}

impl AimdWindow {
    pub fn new(initial: u64, min: u64, max: u64, target_rtt_ns: u64) -> Self {
        Self {
            window: initial,
            min,
            max,
            add_step: 64 * 1024,
            target_rtt_ns,
            rtt: Ewma::new(0.2),
            last_decrease_ns: 0,
            cooldown_ns: 200_000,
        }
    }
}

impl AdmissionPolicy for AimdWindow {
    fn window_bytes(&mut self, now: u64, fb: &Feedback) -> u64 {
        if fb.last_completion_ns > 0 {
            let rtt = self.rtt.update(fb.last_completion_ns as f64);
            if rtt > self.target_rtt_ns as f64 {
                if now.saturating_sub(self.last_decrease_ns) > self.cooldown_ns {
                    self.window = (self.window / 2).max(self.min);
                    self.last_decrease_ns = now;
                }
            } else {
                self.window = (self.window + self.add_step).min(self.max);
            }
        }
        self.window
    }
    fn name(&self) -> &'static str {
        "aimd"
    }
}

/// The regulator: tracks in-flight bytes against the policy window.
///
/// Posting and completion are keyed by `wr_id`: in debug builds the
/// regulator keeps a per-WR byte ledger and asserts that every completion
/// releases exactly the bytes its post reserved. An error completion that
/// released the wrong amount (or a duplicate completion that released
/// twice) would strand window capacity forever — the leak is invisible in
/// steady state and fatal under load, so it is a debug assertion, not a
/// runtime branch.
#[derive(Debug)]
pub struct Regulator {
    policy: Box<dyn AdmissionPolicy>,
    in_flight: u64,
    feedback: Feedback,
    pub admitted: u64,
    pub blocked_checks: u64,
    pub peak_in_flight: u64,
    /// Debug-only per-WR ledger: wr_id -> bytes reserved at post time.
    #[cfg(debug_assertions)]
    ledger: crate::util::fxhash::FxHashMap<u64, u64>,
}

impl Regulator {
    pub fn new(policy: Box<dyn AdmissionPolicy>) -> Self {
        Self {
            policy,
            in_flight: 0,
            feedback: Feedback::default(),
            admitted: 0,
            blocked_checks: 0,
            peak_in_flight: 0,
            #[cfg(debug_assertions)]
            ledger: crate::util::fxhash::FxHashMap::default(),
        }
    }

    pub fn unlimited() -> Self {
        Self::new(Box::new(Unlimited))
    }

    pub fn static_window(bytes: u64) -> Self {
        Self::new(Box::new(StaticWindow(bytes)))
    }

    pub fn in_flight(&self) -> u64 {
        self.in_flight
    }

    /// Swap the admission policy **without** touching the in-flight
    /// accounting or the debug byte ledger: WRs posted under the old
    /// policy still release exactly their reserved bytes. This is what
    /// makes mid-run admission churn (a live window re-size) safe — a
    /// shrink below the current in-flight level simply blocks new
    /// admissions until completions drain it below the new window.
    pub fn set_policy(&mut self, policy: Box<dyn AdmissionPolicy>) {
        self.policy = policy;
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    /// Bytes that may still be admitted right now (merge-queue drains pass
    /// this as the window argument so a closed window leaves requests
    /// queued — where they can still merge).
    pub fn available(&mut self, now_ns: u64) -> u64 {
        let w = self.policy.window_bytes(now_ns, &self.feedback);
        let avail = w.saturating_sub(self.in_flight);
        if avail == 0 {
            self.blocked_checks += 1;
        }
        avail
    }

    /// Record that WR `wr_id` reserved `bytes` of the window.
    pub fn on_post(&mut self, wr_id: u64, bytes: u64) {
        #[cfg(debug_assertions)]
        {
            let prev = self.ledger.insert(wr_id, bytes);
            debug_assert!(
                prev.is_none(),
                "wr_id {wr_id} posted twice without completing"
            );
        }
        #[cfg(not(debug_assertions))]
        let _ = wr_id;
        self.in_flight += bytes;
        self.feedback.in_flight_bytes = self.in_flight;
        self.peak_in_flight = self.peak_in_flight.max(self.in_flight);
        self.admitted += 1;
    }

    /// Record a completion (success *or* error — either way the WR left
    /// the NIC): releases window and feeds RTT to the policy. In debug
    /// builds, asserts `bytes` matches what `wr_id`'s post reserved so a
    /// mismatched release cannot silently strand window capacity.
    pub fn on_complete(&mut self, wr_id: u64, bytes: u64, rtt_ns: u64) {
        #[cfg(debug_assertions)]
        match self.ledger.remove(&wr_id) {
            Some(posted) => debug_assert_eq!(
                posted,
                bytes,
                "wr_id {wr_id} completed {bytes} bytes but posted {posted}"
            ),
            None => panic!("wr_id {wr_id} completed without a matching post"),
        }
        #[cfg(not(debug_assertions))]
        let _ = wr_id;
        debug_assert!(self.in_flight >= bytes, "window release underflow");
        self.in_flight = self.in_flight.saturating_sub(bytes);
        self.feedback.in_flight_bytes = self.in_flight;
        self.feedback.last_completion_ns = rtt_ns;
        self.feedback.rtt_ewma_ns = rtt_ns as f64;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{self, cfg};

    #[test]
    fn unlimited_never_blocks() {
        let mut r = Regulator::unlimited();
        r.on_post(1, u32::MAX as u64);
        assert_eq!(r.available(0), u64::MAX - u32::MAX as u64);
    }

    #[test]
    fn static_window_enforced() {
        let mut r = Regulator::static_window(7 << 20);
        assert_eq!(r.available(0), 7 << 20);
        r.on_post(1, 6 << 20);
        assert_eq!(r.available(0), 1 << 20);
        r.on_post(2, 1 << 20);
        assert_eq!(r.available(0), 0);
        assert_eq!(r.blocked_checks, 1);
        r.on_complete(2, 1 << 20, 10_000);
        assert_eq!(r.available(0), 1 << 20);
    }

    #[test]
    fn peak_tracking() {
        let mut r = Regulator::static_window(10 << 20);
        r.on_post(1, 4 << 20);
        r.on_post(2, 2 << 20);
        r.on_complete(1, 4 << 20, 5_000);
        r.on_post(3, 1 << 20);
        assert_eq!(r.peak_in_flight, 6 << 20);
        assert_eq!(r.in_flight(), 3 << 20);
    }

    /// Satellite: error completions release exactly what their post
    /// reserved — the ledger keeps the window balanced even when every
    /// completion is an error.
    #[test]
    fn error_completions_release_exactly_posted_bytes() {
        let mut r = Regulator::static_window(1 << 20);
        for wr in 0..32u64 {
            r.on_post(wr, 4096);
        }
        assert_eq!(r.in_flight(), 32 * 4096);
        for wr in 0..32u64 {
            // status does not matter to the regulator: the WR left the NIC
            r.on_complete(wr, 4096, 1_000);
        }
        assert_eq!(r.in_flight(), 0, "no stranded window capacity");
        assert_eq!(r.available(0), 1 << 20);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "completed 8192 bytes but posted 4096")]
    fn ledger_catches_mismatched_release() {
        let mut r = Regulator::static_window(1 << 20);
        r.on_post(7, 4096);
        r.on_complete(7, 8192, 1_000);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "completed without a matching post")]
    fn ledger_catches_unposted_completion() {
        let mut r = Regulator::static_window(1 << 20);
        r.on_post(7, 4096);
        r.on_complete(8, 4096, 1_000);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "posted twice")]
    fn ledger_catches_double_post() {
        let mut r = Regulator::static_window(1 << 20);
        r.on_post(7, 4096);
        r.on_post(7, 4096);
    }

    #[test]
    fn aimd_grows_under_target_and_halves_over() {
        let mut p = AimdWindow::new(1 << 20, 256 << 10, 16 << 20, 50_000);
        let fb_fast = Feedback {
            last_completion_ns: 10_000,
            ..Default::default()
        };
        let w0 = p.window_bytes(0, &fb_fast);
        let mut w = w0;
        for t in 1..50u64 {
            w = p.window_bytes(t * 1000, &fb_fast);
        }
        assert!(w > w0, "should grow: {w0} -> {w}");
        // now saturate RTT far above target -> multiplicative decrease
        let fb_slow = Feedback {
            last_completion_ns: 5_000_000,
            ..Default::default()
        };
        let mut w2 = w;
        for t in 50..80u64 {
            w2 = p.window_bytes(t * 1_000_000, &fb_slow);
        }
        assert!(w2 < w / 2 + 1, "should shrink: {w} -> {w2}");
        assert!(w2 >= 256 << 10, "respects floor");
    }

    #[test]
    fn aimd_respects_max() {
        let mut p = AimdWindow::new(15 << 20, 1 << 20, 16 << 20, 1_000_000);
        let fb = Feedback {
            last_completion_ns: 1,
            ..Default::default()
        };
        let mut w = 0;
        for t in 0..100u64 {
            w = p.window_bytes(t, &fb);
        }
        assert_eq!(w, 16 << 20);
    }

    /// Mid-run policy churn keeps the ledger: bytes posted under the old
    /// window release under the new one, and a shrink below the current
    /// in-flight level blocks without stranding capacity.
    #[test]
    fn set_policy_preserves_inflight_accounting() {
        let mut r = Regulator::static_window(8 * 4096);
        r.on_post(1, 6 * 4096);
        r.set_policy(Box::new(StaticWindow(2 * 4096)));
        assert_eq!(r.available(0), 0, "shrunk window blocks new admissions");
        assert_eq!(r.in_flight(), 6 * 4096);
        r.on_complete(1, 6 * 4096, 1_000);
        assert_eq!(r.in_flight(), 0, "old-policy bytes release cleanly");
        assert_eq!(r.available(0), 2 * 4096);
        r.set_policy(Box::new(Unlimited));
        assert_eq!(r.policy_name(), "unlimited");
        assert_eq!(r.available(0), u64::MAX);
    }

    /// Property: in-flight accounting never goes negative and equals
    /// posted-minus-completed at every step.
    #[test]
    fn prop_inflight_accounting() {
        prop::forall(cfg(0xAD0_11), |rng, size| {
            let mut r = Regulator::static_window((1 + rng.gen_below(64)) << 20);
            let mut outstanding: Vec<(u64, u64)> = Vec::new();
            let mut posted: u64 = 0;
            let mut completed: u64 = 0;
            let mut next_wr = 0u64;
            for _ in 0..size * 4 {
                if rng.gen_bool(0.6) || outstanding.is_empty() {
                    let avail = r.available(0);
                    if avail == 0 {
                        continue;
                    }
                    let bytes = (1 + rng.gen_below(32)) * 4096;
                    if bytes > avail {
                        continue;
                    }
                    r.on_post(next_wr, bytes);
                    posted += bytes;
                    outstanding.push((next_wr, bytes));
                    next_wr += 1;
                } else {
                    let i = rng.gen_below(outstanding.len() as u64) as usize;
                    let (wr, bytes) = outstanding.swap_remove(i);
                    r.on_complete(wr, bytes, 1000);
                    completed += bytes;
                }
                if r.in_flight() != posted - completed {
                    return Err(format!(
                        "in_flight {} != posted-completed {}",
                        r.in_flight(),
                        posted - completed
                    ));
                }
            }
            Ok(())
        });
    }
}
