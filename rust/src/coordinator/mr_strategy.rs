//! Memory-region strategy: pre-registered MR pool (`preMR`) vs dynamic
//! registration (`dynMR`) vs the user-space threshold mix (paper §5.1,
//! Fig 4).
//!
//! * `PreMr` — a pool of fixed-size registered slots; posting a write costs
//!   a staging memcpy into the slot, a read costs a memcpy out at
//!   completion. No registration on the hot path, but copies consume CPU
//!   and the copy sits on the critical path.
//! * `DynMr` — register the data buffer itself (SGE) before posting,
//!   deregister at completion. In kernel space registration uses physical
//!   addresses (no PTE walk / NIC translation-cache pressure) and is cheap
//!   at every size; in user space per-page translation makes small
//!   registrations expensive.
//! * `Threshold` — the paper's user-space recommendation: preMR below the
//!   memcpy/registration crossover (~928 KB measured), dynMR above.

use crate::config::FabricConfig;
use crate::util::idlist::IdList;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AddrSpace {
    Kernel,
    User,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum MrMode {
    PreMr,
    DynMr,
    /// Switch to DynMr at-or-above this many bytes.
    Threshold(u64),
}

impl MrMode {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "premr" | "pre" => Ok(Self::PreMr),
            "dynmr" | "dyn" => Ok(Self::DynMr),
            "threshold" | "mixed" => Ok(Self::Threshold(928 * 1024)),
            other => Err(format!("unknown MR mode `{other}`")),
        }
    }

    /// The paper's recommended default per address space (§5.1): dynMR in
    /// kernel space, threshold mix in user space.
    pub fn recommended(space: AddrSpace, cfg: &FabricConfig) -> Self {
        match space {
            AddrSpace::Kernel => Self::DynMr,
            AddrSpace::User => Self::Threshold(cfg.user_crossover_bytes()),
        }
    }

    /// Effective mode for a given transfer size.
    pub fn resolve(self, len: u64) -> ResolvedMr {
        match self {
            MrMode::PreMr => ResolvedMr::PreMr,
            MrMode::DynMr => ResolvedMr::DynMr,
            MrMode::Threshold(t) => {
                if len >= t {
                    ResolvedMr::DynMr
                } else {
                    ResolvedMr::PreMr
                }
            }
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ResolvedMr {
    PreMr,
    DynMr,
}

/// CPU cost charged *before posting* a WR of `len` bytes.
/// preMR writes stage a copy in; reads pay nothing up front.
pub fn post_cost_ns(
    cfg: &FabricConfig,
    mode: MrMode,
    space: AddrSpace,
    len: u64,
    is_write: bool,
) -> u64 {
    match mode.resolve(len) {
        ResolvedMr::PreMr => {
            if is_write {
                cfg.memcpy_ns(len)
            } else {
                0
            }
        }
        ResolvedMr::DynMr => cfg.reg_ns(len, space == AddrSpace::Kernel),
    }
}

/// CPU cost charged *in the completion handler*.
/// preMR reads copy out of the slot; dynMR deregisters.
pub fn completion_cost_ns(
    cfg: &FabricConfig,
    mode: MrMode,
    space: AddrSpace,
    len: u64,
    is_write: bool,
) -> u64 {
    match mode.resolve(len) {
        ResolvedMr::PreMr => {
            if is_write {
                0
            } else {
                cfg.memcpy_ns(len)
            }
        }
        ResolvedMr::DynMr => cfg.dereg_ns(len, space == AddrSpace::Kernel),
    }
}

/// A pool of pre-registered fixed-size MR slots. Exhaustion stalls the
/// posting thread (counted) — one more reason large fixed-block designs
/// (nbdX) lose under memory pressure.
///
/// Allocation-free on the hot path: `acquire_into` fills a caller-owned
/// [`IdList`] (inline up to the SGE merge width, like every other per-WR
/// id set in the engine), the free list and the in-use bitmap are sized
/// once at construction, and the double-free check is an O(1) bitmap
/// lookup instead of an O(n) scan of the free list.
#[derive(Debug)]
pub struct PreMrPool {
    slot_bytes: u64,
    free: Vec<u32>,
    /// O(1) double-free / foreign-slot detection: `in_use[s]` is true
    /// exactly while slot `s` is checked out.
    in_use: Vec<bool>,
    total: u32,
    pub exhausted_events: u64,
}

impl PreMrPool {
    pub fn new(slot_bytes: u64, slots: u32) -> Self {
        Self {
            slot_bytes,
            free: (0..slots).rev().collect(),
            in_use: vec![false; slots as usize],
            total: slots,
            exhausted_events: 0,
        }
    }

    pub fn slot_bytes(&self) -> u64 {
        self.slot_bytes
    }

    pub fn in_use(&self) -> u32 {
        self.total - self.free.len() as u32
    }

    /// Acquire enough slots to stage `len` bytes into `out` (cleared
    /// first); false if exhausted. `out` stays inline (no allocation) up
    /// to [`crate::util::idlist::INLINE_IDS`] slots per WR.
    pub fn acquire_into(&mut self, len: u64, out: &mut IdList) -> bool {
        out.clear();
        let need = len.div_ceil(self.slot_bytes) as usize;
        if self.free.len() < need {
            self.exhausted_events += 1;
            return false;
        }
        for _ in 0..need {
            let s = self.free.pop().unwrap();
            self.in_use[s as usize] = true;
            out.push(s as u64);
        }
        true
    }

    /// Return every slot in `slots` to the pool and clear the list so the
    /// caller can reuse it as scratch.
    pub fn release(&mut self, slots: &mut IdList) {
        for &s in slots.iter() {
            assert!(
                (s as usize) < self.in_use.len() && self.in_use[s as usize],
                "double free (or foreign slot) of MR slot {s}"
            );
            self.in_use[s as usize] = false;
            self.free.push(s as u32);
        }
        slots.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> FabricConfig {
        FabricConfig::default()
    }

    #[test]
    fn kernel_recommended_is_dynmr() {
        assert_eq!(MrMode::recommended(AddrSpace::Kernel, &cfg()), MrMode::DynMr);
    }

    #[test]
    fn user_recommended_is_threshold_near_928k() {
        let m = MrMode::recommended(AddrSpace::User, &cfg());
        match m {
            MrMode::Threshold(t) => {
                let paper = 928 * 1024;
                assert!(
                    (t as f64 - paper as f64).abs() / paper as f64 <= 0.15,
                    "threshold {t}"
                );
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn threshold_resolves_by_size() {
        let m = MrMode::Threshold(928 * 1024);
        assert_eq!(m.resolve(4096), ResolvedMr::PreMr);
        assert_eq!(m.resolve(1 << 20), ResolvedMr::DynMr);
    }

    #[test]
    fn premr_write_costs_copy_upfront_and_nothing_at_completion() {
        let c = cfg();
        let up = post_cost_ns(&c, MrMode::PreMr, AddrSpace::User, 128 << 10, true);
        let down = completion_cost_ns(&c, MrMode::PreMr, AddrSpace::User, 128 << 10, true);
        assert_eq!(up, c.memcpy_ns(128 << 10));
        assert_eq!(down, 0);
    }

    #[test]
    fn premr_read_costs_copy_at_completion() {
        let c = cfg();
        let up = post_cost_ns(&c, MrMode::PreMr, AddrSpace::User, 128 << 10, false);
        let down = completion_cost_ns(&c, MrMode::PreMr, AddrSpace::User, 128 << 10, false);
        assert_eq!(up, 0);
        assert_eq!(down, c.memcpy_ns(128 << 10));
    }

    #[test]
    fn dynmr_kernel_cheaper_than_user() {
        let c = cfg();
        let k = post_cost_ns(&c, MrMode::DynMr, AddrSpace::Kernel, 64 << 10, true);
        let u = post_cost_ns(&c, MrMode::DynMr, AddrSpace::User, 64 << 10, true);
        assert!(k < u, "kernel {k} user {u}");
    }

    #[test]
    fn user_post_cost_crossover_matches_fig4() {
        // Fig 4b measures the *critical-path* cost of staging a message:
        // memcpy-into-preMR vs registering the buffer (deregistration is
        // off the critical path — deferred/batched by real MR caches).
        // preMR cheaper below the ~928 KB crossover, dynMR above.
        let c = cfg();
        let post = |mode: MrMode, len: u64| post_cost_ns(&c, mode, AddrSpace::User, len, true);
        assert!(post(MrMode::PreMr, 64 << 10) < post(MrMode::DynMr, 64 << 10));
        assert!(post(MrMode::PreMr, 4 << 20) > post(MrMode::DynMr, 4 << 20));
    }

    #[test]
    fn kernel_dynmr_beats_premr_at_all_sizes() {
        let c = cfg();
        for len in [4096u64, 64 << 10, 256 << 10, 1 << 20, 8 << 20] {
            let pre = post_cost_ns(&c, MrMode::PreMr, AddrSpace::Kernel, len, true)
                + completion_cost_ns(&c, MrMode::PreMr, AddrSpace::Kernel, len, true);
            let dyn_ = post_cost_ns(&c, MrMode::DynMr, AddrSpace::Kernel, len, true)
                + completion_cost_ns(&c, MrMode::DynMr, AddrSpace::Kernel, len, true);
            assert!(dyn_ < pre, "len={len}: dyn {dyn_} pre {pre}");
        }
    }

    #[test]
    fn pool_acquire_release_roundtrip() {
        let mut p = PreMrPool::new(4096, 4);
        let mut a = IdList::new();
        let mut b = IdList::new();
        assert!(p.acquire_into(4096, &mut a));
        assert_eq!(a.len(), 1);
        assert!(p.acquire_into(8192, &mut b));
        assert_eq!(b.len(), 2);
        assert_eq!(p.in_use(), 3);
        let mut c = IdList::new();
        assert!(!p.acquire_into(8192, &mut c)); // only 1 left
        assert!(c.is_empty(), "failed acquire must not hand out slots");
        assert_eq!(p.exhausted_events, 1);
        p.release(&mut a);
        p.release(&mut b);
        assert!(a.is_empty() && b.is_empty(), "release reclaims the scratch");
        assert_eq!(p.in_use(), 0);
        assert!(p.acquire_into(4 * 4096, &mut c));
        assert_eq!(c.len(), 4);
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn pool_release_catches_double_free() {
        let mut p = PreMrPool::new(4096, 4);
        let mut a = IdList::new();
        assert!(p.acquire_into(4096, &mut a));
        let slot = a[0];
        p.release(&mut a);
        let mut again = IdList::new();
        again.push(slot); // forged second release of the same slot
        p.release(&mut again);
    }

    /// Property: random acquire/release interleavings keep the free list
    /// and the in-use bitmap consistent — no slot is ever handed out
    /// twice, `in_use()` always equals the number of checked-out slots,
    /// and every release makes the slots reacquirable.
    #[test]
    fn prop_pool_roundtrip_conserves_slots() {
        use crate::util::prop::{self, cfg};
        prop::forall(cfg(0x920_07), |rng, size| {
            let total = 1 + rng.gen_below(12) as u32;
            let mut p = PreMrPool::new(4096, total);
            let mut held: Vec<IdList> = Vec::new();
            let mut checked_out = 0u32;
            for _ in 0..size * 4 {
                if rng.gen_bool(0.55) {
                    let want = 1 + rng.gen_below(4);
                    let mut ids = IdList::new();
                    let ok = p.acquire_into(want * 4096, &mut ids);
                    if ok {
                        checked_out += ids.len() as u32;
                        held.push(ids);
                    } else if !ids.is_empty() {
                        return Err("exhausted acquire leaked slots".into());
                    }
                } else if !held.is_empty() {
                    let i = rng.gen_below(held.len() as u64) as usize;
                    let mut ids = held.swap_remove(i);
                    checked_out -= ids.len() as u32;
                    p.release(&mut ids);
                }
                if p.in_use() != checked_out {
                    return Err(format!(
                        "ledger drift: pool says {} in use, test holds {}",
                        p.in_use(),
                        checked_out
                    ));
                }
                let mut seen = vec![false; total as usize];
                for ids in &held {
                    for &s in ids.iter() {
                        if seen[s as usize] {
                            return Err(format!("slot {s} handed out twice"));
                        }
                        seen[s as usize] = true;
                    }
                }
            }
            // drain everything: the pool must come back whole
            for mut ids in held {
                p.release(&mut ids);
            }
            if p.in_use() != 0 {
                return Err("slots lost after full release".into());
            }
            let mut all = IdList::new();
            if !p.acquire_into(u64::from(total) * 4096, &mut all) {
                return Err("full-capacity acquire failed on a drained pool".into());
            }
            Ok(())
        });
    }

    #[test]
    fn parse_modes() {
        assert_eq!(MrMode::parse("premr").unwrap(), MrMode::PreMr);
        assert_eq!(MrMode::parse("dyn").unwrap(), MrMode::DynMr);
        assert!(matches!(
            MrMode::parse("threshold").unwrap(),
            MrMode::Threshold(_)
        ));
        assert!(MrMode::parse("wat").is_err());
    }
}
