//! Node-level abstraction (paper §6): the remote-node map that gives
//! applications user-transparent remote memory through a virtual block
//! device — data distribution, replication placement, and failover order.
//!
//! The paging system replicates each block on 2 remote nodes plus local
//! disk; disk is touched only when every replica has failed (paper §7.1).

use crate::fabric::NodeId;

/// Lifecycle of a remote node in the placement map.
///
/// `Resyncing` is the epoch-based recovery state: the node is reachable
/// (its QPs complete verbs) but it missed writes while it was `Dead` (or
/// while a write replica-copy to it failed), so it is excluded from *both*
/// read and write routing until the engine's resync protocol has replayed
/// the missed ranges from an alive peer. Only then does it return to
/// `Alive`. Without this state a revived replica would serve stale data
/// for every block written during its downtime.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeState {
    /// Serving reads and receiving replicated writes.
    Alive,
    /// Down: routing skips it, in-flight verbs complete in error.
    Dead,
    /// Up but behind: receives only resync repair writes until the
    /// missed-write backlog has been replayed.
    Resyncing,
}

/// Routing decision for a read: the first alive replica, or the explicit
/// disk-fallback signal the paging layer acts on when every replica of the
/// block has failed (paper §7.1: "disk access occurs only when all
/// replication is failed").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReadRoute {
    Node(NodeId),
    DiskFallback,
}

/// Routing decision for a replicated write: the alive targets to fan out
/// to, plus the explicit disk-fallback signal when none are alive.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WriteRoute {
    pub targets: Vec<NodeId>,
    pub disk_fallback: bool,
}

/// Where a block lives: ordered replica list (primary first) + disk flag.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Placement {
    pub replicas: Vec<NodeId>,
    /// Remote address of the block on each replica (same offset on all).
    pub remote_addr: u64,
}

/// A compact per-range **epoch vector**: the recovery metadata one replica
/// publishes about a span of the block space. Stored as disjoint
/// `start → (end, epoch)` ranges; uncovered bytes have epoch 0 ("never
/// written since epochs were minted").
///
/// Two instances drive the engine's donor election (ISSUE 4 / ROADMAP
/// "epoch-vector exchange between donors"):
///
/// * per node, the **applied** vector — the highest write epoch whose data
///   the node's store actually holds, per range;
/// * cluster-wide, the **required** vector — the highest epoch the client
///   has issued per range (the client-visible write floor).
///
/// A replica is a valid repair donor for a range iff its applied vector
/// dominates the required vector over every byte of the range — which is
/// decidable even between two *mutually diverged* resyncing peers, the
/// case the pre-election protocol had to park forever.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct EpochMap {
    map: std::collections::BTreeMap<u64, (u64, u64)>,
}

impl EpochMap {
    /// Raise the epoch of every byte in `[addr, addr + len)` to at least
    /// `epoch` (entries are split where they straddle the span; existing
    /// higher epochs are kept — epochs are monotone per byte).
    pub fn raise(&mut self, addr: u64, len: u64, epoch: u64) {
        if len == 0 || epoch == 0 {
            return;
        }
        let end = addr + len;
        // carve out every overlapping entry, keeping the parts outside the
        // span verbatim and max-merging the parts inside
        let overlapping: Vec<(u64, u64, u64)> = self
            .map
            .range(..end)
            .filter(|&(_, &(e, _))| e > addr)
            .map(|(&s, &(e, ep))| (s, e, ep))
            .collect();
        let mut pieces: Vec<(u64, u64, u64)> = Vec::new();
        for (s, e, ep) in overlapping {
            self.map.remove(&s);
            if s < addr {
                pieces.push((s, addr, ep));
            }
            pieces.push((s.max(addr), e.min(end), ep.max(epoch)));
            if e > end {
                pieces.push((end, e, ep));
            }
        }
        // fill the gaps of the span with the new epoch
        let mut cursor = addr;
        let covered: Vec<(u64, u64)> = pieces
            .iter()
            .filter(|&&(s, _, _)| s >= addr)
            .map(|&(s, e, _)| (s, e.min(end)))
            .collect();
        for (s, e) in covered {
            if s > cursor {
                pieces.push((cursor, s, epoch));
            }
            cursor = cursor.max(e);
        }
        if cursor < end {
            pieces.push((cursor, end, epoch));
        }
        pieces.sort_unstable();
        // coalesce equal-epoch neighbors so the vector stays compact
        for (s, e, ep) in pieces {
            if let Some((&ps, &(pe, pep))) = self.map.range(..=s).next_back() {
                if pe == s && pep == ep {
                    self.map.remove(&ps);
                    self.map.insert(ps, (e, ep));
                    continue;
                }
            }
            self.map.insert(s, (e, ep));
        }
    }

    /// The lowest epoch held anywhere in `[addr, addr + len)` (gaps count
    /// as 0). This is what a donor's validity check uses: the donor must
    /// hold *every* byte of the range at or above the required epoch.
    pub fn min_over(&self, addr: u64, len: u64) -> u64 {
        self.segments(addr, len)
            .into_iter()
            .map(|(_, _, e)| e)
            .min()
            .unwrap_or(0)
    }

    /// The highest epoch held anywhere in `[addr, addr + len)`.
    pub fn max_over(&self, addr: u64, len: u64) -> u64 {
        self.segments(addr, len)
            .into_iter()
            .map(|(_, _, e)| e)
            .max()
            .unwrap_or(0)
    }

    /// Decompose `[addr, addr + len)` into maximal `(addr, len, epoch)`
    /// segments of uniform epoch, covering the whole span (gaps appear as
    /// epoch-0 segments). Election walks these so a single repair chunk
    /// with heterogeneous history elects per uniform sub-range.
    pub fn segments(&self, addr: u64, len: u64) -> Vec<(u64, u64, u64)> {
        let mut out = Vec::new();
        if len == 0 {
            return out;
        }
        let end = addr + len;
        let mut cursor = addr;
        for (&s, &(e, ep)) in self.map.range(..end) {
            if e <= addr {
                continue;
            }
            let s = s.max(addr);
            if s > cursor {
                out.push((cursor, s - cursor, 0));
            }
            let seg_end = e.min(end);
            out.push((s, seg_end - s, ep));
            cursor = seg_end;
        }
        if cursor < end {
            out.push((cursor, end - cursor, 0));
        }
        out
    }

    /// Number of stored ranges (compactness measure).
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// `true` when no byte has a non-zero epoch.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// The stored ranges as `(start, end, epoch)` triples, in address
    /// order. Each stored range has a uniform epoch; gaps (epoch 0) are
    /// not yielded. Pruning walks these.
    pub fn entries(&self) -> impl Iterator<Item = (u64, u64, u64)> + '_ {
        self.map.iter().map(|(&s, &(e, ep))| (s, e, ep))
    }

    /// Erase `[addr, addr + len)` — every byte of the span reverts to
    /// epoch 0 ("no recorded history"), splitting entries that straddle
    /// the boundary. This is the bounding operation for long-running
    /// engines: a range every live replica provably holds at (or above)
    /// the required floor carries no recovery information and can be
    /// forgotten; any later write re-mints a fresh epoch over it.
    pub fn erase(&mut self, addr: u64, len: u64) {
        if len == 0 {
            return;
        }
        let end = addr + len;
        let overlapping: Vec<(u64, u64, u64)> = self
            .map
            .range(..end)
            .filter(|&(_, &(e, _))| e > addr)
            .map(|(&s, &(e, ep))| (s, e, ep))
            .collect();
        for (s, e, ep) in overlapping {
            self.map.remove(&s);
            if s < addr {
                self.map.insert(s, (addr, ep));
            }
            if e > end {
                self.map.insert(end, (e, ep));
            }
        }
    }
}

/// Striped placement of client block space over N remote memory donors.
#[derive(Debug, Clone)]
pub struct NodeMap {
    nodes: usize,
    replicas: usize,
    stripe_bytes: u64,
    states: Vec<NodeState>,
}

impl NodeMap {
    pub fn new(nodes: usize, replicas: usize, stripe_bytes: u64) -> Self {
        assert!(nodes >= 1, "need at least one remote node");
        assert!(replicas >= 1 && replicas <= nodes);
        assert!(stripe_bytes > 0);
        Self {
            nodes,
            replicas,
            stripe_bytes,
            states: vec![NodeState::Alive; nodes],
        }
    }

    pub fn nodes(&self) -> usize {
        self.nodes
    }

    pub fn replicas(&self) -> usize {
        self.replicas
    }

    pub fn stripe_bytes(&self) -> u64 {
        self.stripe_bytes
    }

    /// Mark a node failed/recovered (failure injection, live failover).
    /// `alive = true` promotes straight to [`NodeState::Alive`] — callers
    /// that want the resync protocol go through the engine's
    /// `on_node_up`, which decides between `Alive` and `Resyncing`.
    ///
    /// # Panics
    /// Panics with a descriptive message if `node` is out of range — a
    /// caller naming a node that does not exist is a configuration bug,
    /// not a runtime condition to paper over.
    pub fn set_alive(&mut self, node: NodeId, alive: bool) {
        self.set_state(
            node,
            if alive {
                NodeState::Alive
            } else {
                NodeState::Dead
            },
        );
    }

    /// Set the full lifecycle state (resync protocol).
    ///
    /// # Panics
    /// Panics with a descriptive message if `node` is out of range.
    pub fn set_state(&mut self, node: NodeId, state: NodeState) {
        assert!(
            node < self.nodes,
            "NodeMap::set_state: node {node} out of range (cluster has {} nodes)",
            self.nodes
        );
        self.states[node] = state;
    }

    /// # Panics
    /// Panics with a descriptive message if `node` is out of range.
    pub fn state(&self, node: NodeId) -> NodeState {
        assert!(
            node < self.nodes,
            "NodeMap::state: node {node} out of range (cluster has {} nodes)",
            self.nodes
        );
        self.states[node]
    }

    /// `true` iff the node is fully [`NodeState::Alive`] — a `Resyncing`
    /// node is *not* alive for routing purposes (it may hold stale data).
    ///
    /// # Panics
    /// Panics with a descriptive message if `node` is out of range.
    pub fn is_alive(&self, node: NodeId) -> bool {
        self.state(node) == NodeState::Alive
    }

    pub fn alive_count(&self) -> usize {
        self.states
            .iter()
            .filter(|s| **s == NodeState::Alive)
            .count()
    }

    /// Placement of the block containing `addr`. Replicas are consecutive
    /// nodes starting at the stripe's primary; the remote address is the
    /// client address (donors mirror the client block space — capacity
    /// management stays in the paging layer).
    pub fn place(&self, addr: u64) -> Placement {
        let stripe = addr / self.stripe_bytes;
        let primary = (stripe % self.nodes as u64) as usize;
        let replicas = (0..self.replicas)
            .map(|i| (primary + i) % self.nodes)
            .collect();
        Placement {
            replicas,
            remote_addr: addr,
        }
    }

    /// The ordered replica nodes of the stripe containing `addr`, as an
    /// iterator — the allocation-free form of [`NodeMap::place`] the
    /// engine's hot submit path uses.
    pub fn replicas_of(&self, addr: u64) -> impl Iterator<Item = NodeId> + '_ {
        let stripe = addr / self.stripe_bytes;
        let primary = (stripe % self.nodes as u64) as usize;
        (0..self.replicas).map(move |i| (primary + i) % self.nodes)
    }

    /// Read path: first *alive* replica, else None (→ disk fallback).
    pub fn read_target(&self, addr: u64) -> Option<NodeId> {
        self.place(addr)
            .replicas
            .into_iter()
            .find(|&n| self.is_alive(n))
    }

    /// Write path: all alive replicas. Dead *and* resyncing replicas are
    /// skipped — a resyncing node receives only repair writes, and every
    /// skipped replica is recorded by the engine as a missed range so the
    /// resync protocol replays it before the node serves reads again.
    pub fn write_targets(&self, addr: u64) -> Vec<NodeId> {
        self.place(addr)
            .replicas
            .into_iter()
            .filter(|&n| self.is_alive(n))
            .collect()
    }

    /// Does `[addr, addr + len)` lie entirely within one replication
    /// stripe? The engine's submission path checks this before calling
    /// [`NodeMap::split_stripe_local`], so the common single-stripe
    /// request never allocates a leg list.
    pub fn stripe_local(&self, addr: u64, len: u64) -> bool {
        len == 0 || addr / self.stripe_bytes == (addr + len - 1) / self.stripe_bytes
    }

    /// Split `[addr, addr + len)` into stripe-local `(addr, len)` legs:
    /// each leg lies entirely within one replication stripe, the legs are
    /// in address order, and their concatenation is exactly the input
    /// span. This is what the engine's submission-time request splitter
    /// uses to lift the old "callers must keep requests stripe-local"
    /// contract — a request that straddles stripes is placed (and
    /// replicated) per leg instead of by its first byte.
    pub fn split_stripe_local(&self, addr: u64, len: u64) -> Vec<(u64, u64)> {
        if len == 0 {
            return vec![(addr, 0)];
        }
        let mut legs = Vec::new();
        let mut off = 0u64;
        while off < len {
            let a = addr + off;
            let stripe_left = self.stripe_bytes - (a % self.stripe_bytes);
            let l = stripe_left.min(len - off);
            legs.push((a, l));
            off += l;
        }
        legs
    }

    /// Read routing with the all-replicas-dead case surfaced explicitly.
    pub fn route_read(&self, addr: u64) -> ReadRoute {
        self.route_read_excluding(addr, 0)
    }

    /// Read routing for *failover*: the first alive replica whose bit is
    /// not set in the `attempted` mask (bit n = node n, nodes ≥ 64 are
    /// never considered attempted). When every replica is dead or already
    /// tried, the caller owns the disk path — a revived node that was
    /// already attempted is *not* retried, because blocks written during
    /// its downtime exist only on the surviving replicas.
    pub fn route_read_excluding(&self, addr: u64, attempted: u64) -> ReadRoute {
        let tried = |n: NodeId| n < 64 && attempted & (1u64 << n) != 0;
        let replicas = self.place(addr).replicas;
        match replicas
            .into_iter()
            .find(|&n| self.is_alive(n) && !tried(n))
        {
            Some(n) => ReadRoute::Node(n),
            None => ReadRoute::DiskFallback,
        }
    }

    /// Write routing with the all-replicas-dead case surfaced explicitly.
    pub fn route_write(&self, addr: u64) -> WriteRoute {
        let targets = self.write_targets(addr);
        let disk_fallback = targets.is_empty();
        WriteRoute {
            targets,
            disk_fallback,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{self, cfg};

    #[test]
    fn stripes_rotate_primaries() {
        let m = NodeMap::new(3, 2, 1 << 20);
        assert_eq!(m.place(0).replicas, vec![0, 1]);
        assert_eq!(m.place(1 << 20).replicas, vec![1, 2]);
        assert_eq!(m.place(2 << 20).replicas, vec![2, 0]);
        assert_eq!(m.place(3 << 20).replicas, vec![0, 1]);
    }

    #[test]
    fn same_stripe_same_placement() {
        let m = NodeMap::new(4, 2, 1 << 20);
        assert_eq!(m.place(100).replicas, m.place((1 << 20) - 1).replicas);
    }

    #[test]
    fn read_prefers_primary_then_fails_over() {
        let mut m = NodeMap::new(3, 2, 4096);
        assert_eq!(m.read_target(0), Some(0));
        m.set_alive(0, false);
        assert_eq!(m.read_target(0), Some(1));
        m.set_alive(1, false);
        assert_eq!(m.read_target(0), None); // -> disk
        m.set_alive(0, true);
        assert_eq!(m.read_target(0), Some(0));
    }

    #[test]
    fn write_targets_skip_dead() {
        let mut m = NodeMap::new(3, 2, 4096);
        assert_eq!(m.write_targets(0), vec![0, 1]);
        m.set_alive(1, false);
        assert_eq!(m.write_targets(0), vec![0]);
        m.set_alive(0, false);
        assert!(m.write_targets(0).is_empty());
        assert_eq!(m.alive_count(), 1);
    }

    #[test]
    fn single_node_single_replica() {
        let m = NodeMap::new(1, 1, 4096);
        assert_eq!(m.place(123456).replicas, vec![0]);
    }

    #[test]
    fn route_api_surfaces_disk_fallback() {
        let mut m = NodeMap::new(2, 2, 4096);
        assert_eq!(m.route_read(0), ReadRoute::Node(0));
        assert!(!m.route_write(0).disk_fallback);
        m.set_alive(0, false);
        m.set_alive(1, false);
        assert_eq!(m.route_read(0), ReadRoute::DiskFallback);
        let w = m.route_write(0);
        assert!(w.disk_fallback && w.targets.is_empty());
    }

    #[test]
    fn route_read_excluding_skips_attempted_replicas() {
        let m = NodeMap::new(3, 3, 4096);
        // all alive: primary first, then the untried survivors in order
        // (mask bit n = node n already attempted)
        assert_eq!(m.route_read_excluding(0, 0b000), ReadRoute::Node(0));
        assert_eq!(m.route_read_excluding(0, 0b001), ReadRoute::Node(1));
        assert_eq!(m.route_read_excluding(0, 0b011), ReadRoute::Node(2));
        // every replica tried -> disk, even though all are alive
        assert_eq!(m.route_read_excluding(0, 0b111), ReadRoute::DiskFallback);
    }

    #[test]
    fn route_read_excluding_combines_death_and_attempts() {
        let mut m = NodeMap::new(3, 2, 4096);
        m.set_alive(1, false);
        // stripe 0 replicas are [0, 1]: 0 tried, 1 dead -> disk
        assert_eq!(m.route_read_excluding(0, 0b001), ReadRoute::DiskFallback);
        // a revived node that was already attempted stays excluded
        m.set_alive(1, true);
        assert_eq!(m.route_read_excluding(0, 0b011), ReadRoute::DiskFallback);
        assert_eq!(m.route_read_excluding(0, 0b001), ReadRoute::Node(1));
    }

    #[test]
    fn resyncing_is_excluded_from_both_read_and_write_routing() {
        let mut m = NodeMap::new(3, 2, 4096);
        // stripe 0 replicas are [0, 1]
        m.set_state(0, NodeState::Resyncing);
        assert!(!m.is_alive(0), "resyncing is not alive for routing");
        assert_eq!(m.state(0), NodeState::Resyncing);
        assert_eq!(m.route_read(0), ReadRoute::Node(1));
        assert_eq!(m.write_targets(0), vec![1], "repair writes only");
        assert_eq!(m.alive_count(), 2);
        m.set_state(0, NodeState::Alive);
        assert_eq!(m.route_read(0), ReadRoute::Node(0));
    }

    #[test]
    fn set_alive_maps_onto_the_state_machine() {
        let mut m = NodeMap::new(2, 1, 4096);
        m.set_alive(0, false);
        assert_eq!(m.state(0), NodeState::Dead);
        m.set_alive(0, true);
        assert_eq!(m.state(0), NodeState::Alive);
    }

    #[test]
    fn all_replicas_resyncing_surfaces_disk_fallback() {
        let mut m = NodeMap::new(2, 2, 4096);
        m.set_state(0, NodeState::Resyncing);
        m.set_state(1, NodeState::Resyncing);
        assert_eq!(m.route_read(0), ReadRoute::DiskFallback);
        assert!(m.route_write(0).disk_fallback);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn set_alive_rejects_out_of_range_node() {
        let mut m = NodeMap::new(2, 1, 4096);
        m.set_alive(2, false);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn is_alive_rejects_out_of_range_node() {
        let m = NodeMap::new(3, 1, 4096);
        let _ = m.is_alive(7);
    }

    #[test]
    fn epoch_map_raise_query_and_segments() {
        let mut m = EpochMap::default();
        assert_eq!(m.min_over(0, 100), 0);
        m.raise(10, 10, 3);
        m.raise(30, 10, 5);
        assert_eq!(m.max_over(0, 100), 5);
        assert_eq!(m.min_over(10, 10), 3);
        assert_eq!(m.min_over(10, 30), 0, "gap counts as epoch 0");
        // raising across both splits nothing below the existing epochs
        m.raise(0, 50, 4);
        assert_eq!(m.min_over(0, 50), 4);
        assert_eq!(m.max_over(0, 50), 5, "higher epoch survives the raise");
        let segs = m.segments(0, 50);
        assert_eq!(segs.iter().map(|&(_, l, _)| l).sum::<u64>(), 50);
        assert!(segs.windows(2).all(|w| w[0].0 + w[0].1 == w[1].0));
        assert_eq!(m.segments(30, 10), vec![(30, 10, 5)]);
    }

    #[test]
    fn epoch_map_coalesces_equal_neighbors() {
        let mut m = EpochMap::default();
        m.raise(0, 10, 2);
        m.raise(10, 10, 2);
        assert_eq!(m.len(), 1, "adjacent equal epochs coalesce");
        m.raise(5, 10, 2);
        assert_eq!(m.len(), 1);
        assert!(!m.is_empty());
    }

    #[test]
    fn epoch_map_erase_and_entries() {
        let mut m = EpochMap::default();
        m.raise(0, 100, 3);
        m.raise(200, 50, 7);
        assert_eq!(m.entries().count(), 2);
        // punch a hole: the straddled entry splits, epochs preserved
        m.erase(40, 20);
        let got: Vec<(u64, u64, u64)> = m.entries().collect();
        assert_eq!(got, vec![(0, 40, 3), (60, 100, 3), (200, 250, 7)]);
        assert_eq!(m.min_over(0, 100), 0, "erased span reads epoch 0");
        assert_eq!(m.max_over(0, 40), 3);
        // exact erase empties an entry; erasing a gap is a no-op
        m.erase(200, 50);
        m.erase(120, 30);
        assert_eq!(m.entries().count(), 2);
        m.erase(0, 1000);
        assert!(m.is_empty());
        m.erase(0, 0);
        assert!(m.is_empty());
    }

    /// Property: erase agrees with the naive per-byte model (raising and
    /// erasing at random), including entry splitting at both boundaries.
    #[test]
    fn prop_epoch_map_erase_matches_naive_model() {
        prop::forall(cfg(0xE8A5E), |rng, size| {
            const SPAN: u64 = 200;
            let mut m = EpochMap::default();
            let mut model = [0u64; SPAN as usize];
            for _ in 0..size {
                let addr = rng.gen_below(SPAN);
                let len = 1 + rng.gen_below(SPAN - addr);
                if rng.gen_bool(0.65) {
                    let epoch = 1 + rng.gen_below(12);
                    m.raise(addr, len, epoch);
                    for b in addr..addr + len {
                        model[b as usize] = model[b as usize].max(epoch);
                    }
                } else {
                    m.erase(addr, len);
                    for b in addr..addr + len {
                        model[b as usize] = 0;
                    }
                }
                let qa = rng.gen_below(SPAN);
                let ql = 1 + rng.gen_below(SPAN - qa);
                let naive_min = (qa..qa + ql).map(|b| model[b as usize]).min().unwrap();
                let naive_max = (qa..qa + ql).map(|b| model[b as usize]).max().unwrap();
                if m.min_over(qa, ql) != naive_min || m.max_over(qa, ql) != naive_max {
                    return Err(format!("min/max disagree at ({qa},{ql})"));
                }
            }
            Ok(())
        });
    }

    /// Property: EpochMap agrees with a naive per-byte epoch model under
    /// random raises — min/max queries and full-span segment coverage.
    #[test]
    fn prop_epoch_map_matches_naive_model() {
        prop::forall(cfg(0xE90C), |rng, size| {
            const SPAN: u64 = 256;
            let mut m = EpochMap::default();
            let mut model = [0u64; SPAN as usize];
            for _ in 0..size {
                let addr = rng.gen_below(SPAN);
                let len = 1 + rng.gen_below(SPAN - addr);
                let epoch = 1 + rng.gen_below(16);
                m.raise(addr, len, epoch);
                for b in addr..addr + len {
                    model[b as usize] = model[b as usize].max(epoch);
                }
                let qa = rng.gen_below(SPAN);
                let ql = 1 + rng.gen_below(SPAN - qa);
                let naive_min = (qa..qa + ql).map(|b| model[b as usize]).min().unwrap();
                let naive_max = (qa..qa + ql).map(|b| model[b as usize]).max().unwrap();
                if m.min_over(qa, ql) != naive_min {
                    return Err(format!(
                        "min_over({qa},{ql}) = {} != naive {naive_min}",
                        m.min_over(qa, ql)
                    ));
                }
                if m.max_over(qa, ql) != naive_max {
                    return Err(format!("max_over mismatch at ({qa},{ql})"));
                }
                // segments tile the query span and agree with the model
                let segs = m.segments(qa, ql);
                let mut cursor = qa;
                for (s, l, e) in segs {
                    if s != cursor {
                        return Err(format!("segment gap at {s} (cursor {cursor})"));
                    }
                    for b in s..s + l {
                        if model[b as usize] != e {
                            return Err(format!("segment epoch {e} != model at byte {b}"));
                        }
                    }
                    cursor = s + l;
                }
                if cursor != qa + ql {
                    return Err("segments do not cover the span".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn split_stripe_local_basic() {
        let m = NodeMap::new(3, 2, 1 << 20);
        // fully inside one stripe: one leg, verbatim
        assert_eq!(m.split_stripe_local(4096, 8192), vec![(4096, 8192)]);
        // straddles one boundary: two legs
        let legs = m.split_stripe_local((1 << 20) - 4096, 3 * 4096);
        assert_eq!(legs, vec![((1 << 20) - 4096, 4096), (1 << 20, 2 * 4096)]);
        // spans three stripes
        let legs = m.split_stripe_local((1 << 20) - 1, (2 << 20) + 2);
        assert_eq!(legs.len(), 3);
    }

    /// Property: the splitter's legs exactly cover the original span in
    /// order, and no leg crosses a stripe boundary.
    #[test]
    fn prop_split_stripe_local_covers_exactly() {
        prop::forall(cfg(0x5_111_7), |rng, size| {
            let stripe = 1 << (12 + rng.gen_below(9)); // 4 KiB .. 1 MiB
            let m = NodeMap::new(4, 2, stripe);
            for _ in 0..size {
                let addr = rng.gen_below(1 << 24);
                let len = 1 + rng.gen_below(4 * stripe);
                let legs = m.split_stripe_local(addr, len);
                let mut cursor = addr;
                for &(a, l) in &legs {
                    if a != cursor {
                        return Err(format!("leg at {a} does not continue {cursor}"));
                    }
                    if l == 0 {
                        return Err("empty leg".into());
                    }
                    if a / stripe != (a + l - 1) / stripe {
                        return Err(format!("leg ({a},{l}) crosses a stripe boundary"));
                    }
                    cursor = a + l;
                }
                if cursor != addr + len {
                    return Err(format!(
                        "legs cover [{addr},{cursor}) instead of [{addr},{})",
                        addr + len
                    ));
                }
            }
            Ok(())
        });
    }

    /// Property: replicas are always distinct, alive-filtered, and the
    /// read target is the first alive replica.
    #[test]
    fn prop_placement_invariants() {
        prop::forall(cfg(0x0D0_3), |rng, size| {
            let nodes = 1 + rng.gen_below(10) as usize;
            let replicas = 1 + rng.gen_below(nodes as u64) as usize;
            let mut m = NodeMap::new(nodes, replicas, 4096);
            for _ in 0..size {
                let n = rng.gen_below(nodes as u64) as usize;
                m.set_alive(n, rng.gen_bool(0.7));
            }
            for _ in 0..size {
                let addr = rng.gen_below(1 << 30);
                let p = m.place(addr);
                let set: std::collections::BTreeSet<_> = p.replicas.iter().collect();
                if set.len() != p.replicas.len() {
                    return Err("duplicate replicas".into());
                }
                if p.replicas.len() != replicas {
                    return Err("wrong replica count".into());
                }
                let rt = m.read_target(addr);
                let expect = p.replicas.iter().copied().find(|&n| m.is_alive(n));
                if rt != expect {
                    return Err(format!("read target {rt:?} != {expect:?}"));
                }
                for w in m.write_targets(addr) {
                    if !m.is_alive(w) {
                        return Err("write target dead".into());
                    }
                }
            }
            Ok(())
        });
    }
}
