//! Node-level abstraction (paper §6): the remote-node map that gives
//! applications user-transparent remote memory through a virtual block
//! device — data distribution, replication placement, and failover order.
//!
//! The paging system replicates each block on 2 remote nodes plus local
//! disk; disk is touched only when every replica has failed (paper §7.1).

use crate::fabric::NodeId;

/// Lifecycle of a remote node in the placement map.
///
/// `Resyncing` is the epoch-based recovery state: the node is reachable
/// (its QPs complete verbs) but it missed writes while it was `Dead` (or
/// while a write replica-copy to it failed), so it is excluded from *both*
/// read and write routing until the engine's resync protocol has replayed
/// the missed ranges from an alive peer. Only then does it return to
/// `Alive`. Without this state a revived replica would serve stale data
/// for every block written during its downtime.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeState {
    /// Serving reads and receiving replicated writes.
    Alive,
    /// Down: routing skips it, in-flight verbs complete in error.
    Dead,
    /// Up but behind: receives only resync repair writes until the
    /// missed-write backlog has been replayed.
    Resyncing,
}

/// Routing decision for a read: the first alive replica, or the explicit
/// disk-fallback signal the paging layer acts on when every replica of the
/// block has failed (paper §7.1: "disk access occurs only when all
/// replication is failed").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReadRoute {
    Node(NodeId),
    DiskFallback,
}

/// Routing decision for a replicated write: the alive targets to fan out
/// to, plus the explicit disk-fallback signal when none are alive.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WriteRoute {
    pub targets: Vec<NodeId>,
    pub disk_fallback: bool,
}

/// Where a block lives: ordered replica list (primary first) + disk flag.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Placement {
    pub replicas: Vec<NodeId>,
    /// Remote address of the block on each replica (same offset on all).
    pub remote_addr: u64,
}

/// Striped placement of client block space over N remote memory donors.
#[derive(Debug, Clone)]
pub struct NodeMap {
    nodes: usize,
    replicas: usize,
    stripe_bytes: u64,
    states: Vec<NodeState>,
}

impl NodeMap {
    pub fn new(nodes: usize, replicas: usize, stripe_bytes: u64) -> Self {
        assert!(nodes >= 1, "need at least one remote node");
        assert!(replicas >= 1 && replicas <= nodes);
        assert!(stripe_bytes > 0);
        Self {
            nodes,
            replicas,
            stripe_bytes,
            states: vec![NodeState::Alive; nodes],
        }
    }

    pub fn nodes(&self) -> usize {
        self.nodes
    }

    pub fn replicas(&self) -> usize {
        self.replicas
    }

    pub fn stripe_bytes(&self) -> u64 {
        self.stripe_bytes
    }

    /// Mark a node failed/recovered (failure injection, live failover).
    /// `alive = true` promotes straight to [`NodeState::Alive`] — callers
    /// that want the resync protocol go through the engine's
    /// `on_node_up`, which decides between `Alive` and `Resyncing`.
    ///
    /// # Panics
    /// Panics with a descriptive message if `node` is out of range — a
    /// caller naming a node that does not exist is a configuration bug,
    /// not a runtime condition to paper over.
    pub fn set_alive(&mut self, node: NodeId, alive: bool) {
        self.set_state(
            node,
            if alive {
                NodeState::Alive
            } else {
                NodeState::Dead
            },
        );
    }

    /// Set the full lifecycle state (resync protocol).
    ///
    /// # Panics
    /// Panics with a descriptive message if `node` is out of range.
    pub fn set_state(&mut self, node: NodeId, state: NodeState) {
        assert!(
            node < self.nodes,
            "NodeMap::set_state: node {node} out of range (cluster has {} nodes)",
            self.nodes
        );
        self.states[node] = state;
    }

    /// # Panics
    /// Panics with a descriptive message if `node` is out of range.
    pub fn state(&self, node: NodeId) -> NodeState {
        assert!(
            node < self.nodes,
            "NodeMap::state: node {node} out of range (cluster has {} nodes)",
            self.nodes
        );
        self.states[node]
    }

    /// `true` iff the node is fully [`NodeState::Alive`] — a `Resyncing`
    /// node is *not* alive for routing purposes (it may hold stale data).
    ///
    /// # Panics
    /// Panics with a descriptive message if `node` is out of range.
    pub fn is_alive(&self, node: NodeId) -> bool {
        self.state(node) == NodeState::Alive
    }

    pub fn alive_count(&self) -> usize {
        self.states
            .iter()
            .filter(|s| **s == NodeState::Alive)
            .count()
    }

    /// Placement of the block containing `addr`. Replicas are consecutive
    /// nodes starting at the stripe's primary; the remote address is the
    /// client address (donors mirror the client block space — capacity
    /// management stays in the paging layer).
    pub fn place(&self, addr: u64) -> Placement {
        let stripe = addr / self.stripe_bytes;
        let primary = (stripe % self.nodes as u64) as usize;
        let replicas = (0..self.replicas)
            .map(|i| (primary + i) % self.nodes)
            .collect();
        Placement {
            replicas,
            remote_addr: addr,
        }
    }

    /// Read path: first *alive* replica, else None (→ disk fallback).
    pub fn read_target(&self, addr: u64) -> Option<NodeId> {
        self.place(addr)
            .replicas
            .into_iter()
            .find(|&n| self.is_alive(n))
    }

    /// Write path: all alive replicas. Dead *and* resyncing replicas are
    /// skipped — a resyncing node receives only repair writes, and every
    /// skipped replica is recorded by the engine as a missed range so the
    /// resync protocol replays it before the node serves reads again.
    pub fn write_targets(&self, addr: u64) -> Vec<NodeId> {
        self.place(addr)
            .replicas
            .into_iter()
            .filter(|&n| self.is_alive(n))
            .collect()
    }

    /// Read routing with the all-replicas-dead case surfaced explicitly.
    pub fn route_read(&self, addr: u64) -> ReadRoute {
        self.route_read_excluding(addr, 0)
    }

    /// Read routing for *failover*: the first alive replica whose bit is
    /// not set in the `attempted` mask (bit n = node n, nodes ≥ 64 are
    /// never considered attempted). When every replica is dead or already
    /// tried, the caller owns the disk path — a revived node that was
    /// already attempted is *not* retried, because blocks written during
    /// its downtime exist only on the surviving replicas.
    pub fn route_read_excluding(&self, addr: u64, attempted: u64) -> ReadRoute {
        let tried = |n: NodeId| n < 64 && attempted & (1u64 << n) != 0;
        let replicas = self.place(addr).replicas;
        match replicas
            .into_iter()
            .find(|&n| self.is_alive(n) && !tried(n))
        {
            Some(n) => ReadRoute::Node(n),
            None => ReadRoute::DiskFallback,
        }
    }

    /// Write routing with the all-replicas-dead case surfaced explicitly.
    pub fn route_write(&self, addr: u64) -> WriteRoute {
        let targets = self.write_targets(addr);
        let disk_fallback = targets.is_empty();
        WriteRoute {
            targets,
            disk_fallback,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{self, cfg};

    #[test]
    fn stripes_rotate_primaries() {
        let m = NodeMap::new(3, 2, 1 << 20);
        assert_eq!(m.place(0).replicas, vec![0, 1]);
        assert_eq!(m.place(1 << 20).replicas, vec![1, 2]);
        assert_eq!(m.place(2 << 20).replicas, vec![2, 0]);
        assert_eq!(m.place(3 << 20).replicas, vec![0, 1]);
    }

    #[test]
    fn same_stripe_same_placement() {
        let m = NodeMap::new(4, 2, 1 << 20);
        assert_eq!(m.place(100).replicas, m.place((1 << 20) - 1).replicas);
    }

    #[test]
    fn read_prefers_primary_then_fails_over() {
        let mut m = NodeMap::new(3, 2, 4096);
        assert_eq!(m.read_target(0), Some(0));
        m.set_alive(0, false);
        assert_eq!(m.read_target(0), Some(1));
        m.set_alive(1, false);
        assert_eq!(m.read_target(0), None); // -> disk
        m.set_alive(0, true);
        assert_eq!(m.read_target(0), Some(0));
    }

    #[test]
    fn write_targets_skip_dead() {
        let mut m = NodeMap::new(3, 2, 4096);
        assert_eq!(m.write_targets(0), vec![0, 1]);
        m.set_alive(1, false);
        assert_eq!(m.write_targets(0), vec![0]);
        m.set_alive(0, false);
        assert!(m.write_targets(0).is_empty());
        assert_eq!(m.alive_count(), 1);
    }

    #[test]
    fn single_node_single_replica() {
        let m = NodeMap::new(1, 1, 4096);
        assert_eq!(m.place(123456).replicas, vec![0]);
    }

    #[test]
    fn route_api_surfaces_disk_fallback() {
        let mut m = NodeMap::new(2, 2, 4096);
        assert_eq!(m.route_read(0), ReadRoute::Node(0));
        assert!(!m.route_write(0).disk_fallback);
        m.set_alive(0, false);
        m.set_alive(1, false);
        assert_eq!(m.route_read(0), ReadRoute::DiskFallback);
        let w = m.route_write(0);
        assert!(w.disk_fallback && w.targets.is_empty());
    }

    #[test]
    fn route_read_excluding_skips_attempted_replicas() {
        let m = NodeMap::new(3, 3, 4096);
        // all alive: primary first, then the untried survivors in order
        // (mask bit n = node n already attempted)
        assert_eq!(m.route_read_excluding(0, 0b000), ReadRoute::Node(0));
        assert_eq!(m.route_read_excluding(0, 0b001), ReadRoute::Node(1));
        assert_eq!(m.route_read_excluding(0, 0b011), ReadRoute::Node(2));
        // every replica tried -> disk, even though all are alive
        assert_eq!(m.route_read_excluding(0, 0b111), ReadRoute::DiskFallback);
    }

    #[test]
    fn route_read_excluding_combines_death_and_attempts() {
        let mut m = NodeMap::new(3, 2, 4096);
        m.set_alive(1, false);
        // stripe 0 replicas are [0, 1]: 0 tried, 1 dead -> disk
        assert_eq!(m.route_read_excluding(0, 0b001), ReadRoute::DiskFallback);
        // a revived node that was already attempted stays excluded
        m.set_alive(1, true);
        assert_eq!(m.route_read_excluding(0, 0b011), ReadRoute::DiskFallback);
        assert_eq!(m.route_read_excluding(0, 0b001), ReadRoute::Node(1));
    }

    #[test]
    fn resyncing_is_excluded_from_both_read_and_write_routing() {
        let mut m = NodeMap::new(3, 2, 4096);
        // stripe 0 replicas are [0, 1]
        m.set_state(0, NodeState::Resyncing);
        assert!(!m.is_alive(0), "resyncing is not alive for routing");
        assert_eq!(m.state(0), NodeState::Resyncing);
        assert_eq!(m.route_read(0), ReadRoute::Node(1));
        assert_eq!(m.write_targets(0), vec![1], "repair writes only");
        assert_eq!(m.alive_count(), 2);
        m.set_state(0, NodeState::Alive);
        assert_eq!(m.route_read(0), ReadRoute::Node(0));
    }

    #[test]
    fn set_alive_maps_onto_the_state_machine() {
        let mut m = NodeMap::new(2, 1, 4096);
        m.set_alive(0, false);
        assert_eq!(m.state(0), NodeState::Dead);
        m.set_alive(0, true);
        assert_eq!(m.state(0), NodeState::Alive);
    }

    #[test]
    fn all_replicas_resyncing_surfaces_disk_fallback() {
        let mut m = NodeMap::new(2, 2, 4096);
        m.set_state(0, NodeState::Resyncing);
        m.set_state(1, NodeState::Resyncing);
        assert_eq!(m.route_read(0), ReadRoute::DiskFallback);
        assert!(m.route_write(0).disk_fallback);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn set_alive_rejects_out_of_range_node() {
        let mut m = NodeMap::new(2, 1, 4096);
        m.set_alive(2, false);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn is_alive_rejects_out_of_range_node() {
        let m = NodeMap::new(3, 1, 4096);
        let _ = m.is_alive(7);
    }

    /// Property: replicas are always distinct, alive-filtered, and the
    /// read target is the first alive replica.
    #[test]
    fn prop_placement_invariants() {
        prop::forall(cfg(0x0D0_3), |rng, size| {
            let nodes = 1 + rng.gen_below(10) as usize;
            let replicas = 1 + rng.gen_below(nodes as u64) as usize;
            let mut m = NodeMap::new(nodes, replicas, 4096);
            for _ in 0..size {
                let n = rng.gen_below(nodes as u64) as usize;
                m.set_alive(n, rng.gen_bool(0.7));
            }
            for _ in 0..size {
                let addr = rng.gen_below(1 << 30);
                let p = m.place(addr);
                let set: std::collections::BTreeSet<_> = p.replicas.iter().collect();
                if set.len() != p.replicas.len() {
                    return Err("duplicate replicas".into());
                }
                if p.replicas.len() != replicas {
                    return Err("wrong replica count".into());
                }
                let rt = m.read_target(addr);
                let expect = p.replicas.iter().copied().find(|&n| m.is_alive(n));
                if rt != expect {
                    return Err(format!("read target {rt:?} != {expect:?}"));
                }
                for w in m.write_targets(addr) {
                    if !m.is_alive(w) {
                        return Err("write target dead".into());
                    }
                }
            }
            Ok(())
        });
    }
}
