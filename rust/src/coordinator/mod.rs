//! The RDMAbox coordinator — the paper's contribution (L3).
//!
//! * [`merge_queue`] — the cross-thread I/O merge queue of Load-aware
//!   Batching (§5.1).
//! * [`batching`] — the batch planner: Single / Batching-on-MR / Doorbell /
//!   Hybrid.
//! * [`mr_strategy`] — preMR pool vs dynMR registration vs the user-space
//!   threshold mix (§5.1, Fig 4).
//! * [`mr_cache`] — the pinning-free path: a clock cache of registration
//!   spans with lazy registration, batched deregistration and a
//!   pinned-bytes cap (NP-RDMA-style, beyond the paper's static modes).
//! * [`regulator`] — window-based RDMA-I/O admission control with a
//!   pluggable policy hook (§5.1, Fig 8).
//! * [`polling`] — WC-handling state machines: Busy / Event / EventBatch /
//!   Adaptive / HybridTimer / SCQ topology (§5.2).
//! * [`channel`] — multi-QP channels per remote node (§6.1).
//! * [`node`] — the node-level abstraction: placement, replication,
//!   failover order (§6).
//! * [`gossip`] — the inter-engine anti-entropy plane: epoch vectors,
//!   required floors, node-state transitions and disk-span ownership
//!   exchanged between peer engines (ROADMAP item 1 — many client
//!   hosts sharing one replica set).
//! * [`engine`] — the [`engine::IoEngine`] pipeline composing all of the
//!   above: sharded merge queues (one per QP) → batch planner → admission
//!   window → replication-aware retirement. The single submission path
//!   both fabric backends drive.
//! * [`spec`] — the [`spec::EngineSpec`] builder, the one construction
//!   surface every backend builds its pipeline from.
//!
//! Everything here is pure, synchronous policy code — the same objects are
//! driven by the discrete-event fabric (figures) and by the live loopback
//! fabric (examples).

pub mod batching;
pub mod channel;
pub mod engine;
pub mod gossip;
pub mod merge_queue;
pub mod mr_cache;
pub mod mr_strategy;
pub mod node;
pub mod polling;
pub mod regulator;
pub mod spec;

pub use spec::EngineSpec;

use crate::config::FabricConfig;
use batching::{BatchLimits, BatchMode};
use mr_strategy::{AddrSpace, MrMode};
use polling::PollingMode;

/// A complete design point of the I/O stack: RDMAbox itself is one of
/// these, and each baseline (nbdX, Accelio, Octopus, GlusterFS) is another
/// — this is exactly how the paper characterizes its comparison targets
/// (§7.2).
#[derive(Debug, Clone)]
pub struct StackConfig {
    pub name: String,
    pub batch: BatchMode,
    pub limits: BatchLimits,
    pub mr: MrMode,
    pub space: AddrSpace,
    pub polling: PollingMode,
    /// QPs (channels) per remote node.
    pub qps_per_node: usize,
    /// Admission-control window in bytes; None = unlimited.
    pub window_bytes: Option<u64>,
    /// Two-sided verbs require remote CPU handling per message.
    pub two_sided: bool,
    /// Server-side staging copy (Accelio/GlusterFS receive path).
    pub server_copy: bool,
    /// Fixed block I/O size: requests are rounded up to this (nbdX 128K /
    /// 512K). None = native request granularity (RDMAbox page granularity).
    pub fixed_block: Option<u64>,
}

impl StackConfig {
    /// RDMAbox kernel-space defaults: hybrid batching, dynMR, adaptive
    /// polling, 4 channels, ~7 MB admission window (§6.1 measurement).
    pub fn rdmabox(cfg: &FabricConfig) -> Self {
        Self {
            name: "RDMAbox".into(),
            batch: BatchMode::Hybrid,
            limits: BatchLimits {
                max_sge: cfg.max_sge,
                max_chain: cfg.max_doorbell_chain,
                max_wr_bytes: 1 << 20,
            },
            mr: MrMode::DynMr,
            space: AddrSpace::Kernel,
            polling: PollingMode::Adaptive {
                batch: 16,
                max_retry: 120,
            },
            qps_per_node: 4,
            // "window size can be up to an upper-limit of NIC capability"
            // (§5.1): at page granularity that is ~the WQE-cache capability
            // in pages; the paper's 7 MB figure is the same limit at its
            // 128 KB block fragmentation
            window_bytes: Some(32 * 4096),
            two_sided: false,
            server_copy: false,
            fixed_block: None,
        }
    }

    /// RDMAbox user-space library defaults (RFS): threshold MR mix.
    pub fn rdmabox_user(cfg: &FabricConfig) -> Self {
        Self {
            name: "RDMAbox-user".into(),
            mr: MrMode::recommended(AddrSpace::User, cfg),
            space: AddrSpace::User,
            limits: BatchLimits {
                max_sge: cfg.max_sge,
                max_chain: cfg.max_doorbell_chain,
                // smaller merged WRs keep the FUSE pipeline smooth (a 1MB
                // WR completes its chunks in lockstep)
                max_wr_bytes: 256 << 10,
            },
            // user-space RFS moves 128KB FUSE chunks: the same NIC-capability
            // limit expressed at that fragmentation (the paper's 7MB)
            window_bytes: Some(7 << 20),
            ..Self::rdmabox(cfg)
        }
    }

    pub fn with_batch(mut self, b: BatchMode) -> Self {
        self.batch = b;
        self
    }

    pub fn with_mr(mut self, m: MrMode) -> Self {
        self.mr = m;
        self
    }

    pub fn with_polling(mut self, p: PollingMode) -> Self {
        self.polling = p;
        self
    }

    pub fn with_qps(mut self, k: usize) -> Self {
        self.qps_per_node = k;
        self
    }

    pub fn with_window(mut self, w: Option<u64>) -> Self {
        self.window_bytes = w;
        self
    }

    pub fn with_name(mut self, n: &str) -> Self {
        self.name = n.into();
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rdmabox_default_matches_paper() {
        let cfg = FabricConfig::default();
        let s = StackConfig::rdmabox(&cfg);
        assert_eq!(s.batch, BatchMode::Hybrid);
        assert_eq!(s.mr, MrMode::DynMr);
        assert_eq!(s.qps_per_node, 4);
        assert_eq!(s.window_bytes, Some(32 * 4096));
        assert!(!s.two_sided);
        assert!(s.fixed_block.is_none());
        assert!(matches!(
            s.polling,
            PollingMode::Adaptive {
                max_retry: 120,
                ..
            }
        ));
    }

    #[test]
    fn user_variant_uses_threshold_mr() {
        let cfg = FabricConfig::default();
        let s = StackConfig::rdmabox_user(&cfg);
        assert!(matches!(s.mr, MrMode::Threshold(_)));
        assert_eq!(s.space, AddrSpace::User);
    }

    #[test]
    fn builder_methods_chain() {
        let cfg = FabricConfig::default();
        let s = StackConfig::rdmabox(&cfg)
            .with_batch(BatchMode::Single)
            .with_qps(1)
            .with_window(None)
            .with_name("ablation");
        assert_eq!(s.batch, BatchMode::Single);
        assert_eq!(s.qps_per_node, 1);
        assert_eq!(s.window_bytes, None);
        assert_eq!(s.name, "ablation");
    }
}
