//! Inter-engine anti-entropy gossip (ROADMAP item 1): the coordination
//! plane that turns the single-client `IoEngine` into one member of a
//! multi-engine cluster.
//!
//! Each engine periodically exports a [`GossipDelta`] — its epoch
//! counter, required floor, per-node applied vectors, node-state
//! transitions and disk-surrender log — and absorbs the deltas of its
//! peers. Every merge is a semilattice join (epoch max-merge, range
//! union, last-writer-wins on node states with a deterministic severity
//! tie-break), so the protocol is idempotent and commutative: message
//! loss, reordering and duplication can delay convergence but never
//! corrupt it. Two engines that exchange deltas in both directions and
//! then quiesce hold identical [`gossip fingerprints`].
//!
//! The delta carries *full state* (anti-entropy, not rumor mongering):
//! cheap at the vector sizes the engine keeps (the required floor is
//! pruned by `prune_epoch_floor`, missed ranges drain through resync),
//! and immune to the delivery-order hazards a diff-based protocol would
//! have to track. The only cursor-style state is the disk-surrender
//! log, which is append-only per engine and consumed by index.
//!
//! Epoch minting is interleaved per engine (engine `i` of `n` mints
//! `i + 1, i + n + 1, i + 2n + 1, …`), so two engines writing the same
//! range under a partition can never mint the same epoch — the higher
//! epoch wins deterministically at every replica, exactly like the
//! single-engine monotone-epoch rule.
//!
//! [`gossip fingerprints`]: crate::coordinator::engine::IoEngine::gossip_fingerprint

use crate::coordinator::node::NodeState;
use crate::metrics::GossipStats;

/// Wire code for [`NodeState::Alive`] (lowest severity).
pub const STATE_ALIVE: u8 = 0;
/// Wire code for [`NodeState::Resyncing`].
pub const STATE_RESYNCING: u8 = 1;
/// Wire code for [`NodeState::Dead`] (highest severity).
pub const STATE_DEAD: u8 = 2;

/// Severity-ordered wire code of a node state. On a version tie the
/// *more severe* state wins on both sides of an exchange, so a
/// simultaneous `Alive` vs `Dead` disagreement at the same version
/// resolves identically everywhere.
pub fn state_code(s: NodeState) -> u8 {
    match s {
        NodeState::Alive => STATE_ALIVE,
        NodeState::Resyncing => STATE_RESYNCING,
        NodeState::Dead => STATE_DEAD,
    }
}

/// Inverse of [`state_code`]; `None` for an unknown wire code.
pub fn state_from_code(c: u8) -> Option<NodeState> {
    match c {
        STATE_ALIVE => Some(NodeState::Alive),
        STATE_RESYNCING => Some(NodeState::Resyncing),
        STATE_DEAD => Some(NodeState::Dead),
        _ => None,
    }
}

/// Per-engine gossip bookkeeping, attached to an `IoEngine` by
/// `EngineSpec::gossip(engine_id, engines)`. The epoch-vector content
/// itself stays in the engine's resync ledgers; this tracks what gossip
/// adds: the interleaved mint counter, per-peer round/log cursors,
/// node-state versions and the append-only disk-surrender log.
#[derive(Debug, Clone)]
pub struct GossipState {
    /// This engine's slot in the interleaved epoch space.
    pub engine_id: usize,
    /// Total peer engines sharing the epoch space (≥ 2).
    pub engines: usize,
    /// Rounds this engine has exported (stamped into each delta).
    pub round: u64,
    /// Highest round absorbed per peer engine — older or duplicate
    /// deltas are dropped before any merge work (the alloc-free path).
    pub seen_round: Vec<u64>,
    /// LWW version per cluster node: bumped on every local state
    /// transition, max-adopted from peers.
    pub node_versions: Vec<u64>,
    /// Interleaved mint counter: local mints increment it, absorbs
    /// max-merge it (Lamport-style), so epochs stay globally unique
    /// *and* roughly ordered across engines.
    pub counter: u64,
    /// Append-only log of disk surrenders this engine performed, in
    /// order. Peers consume it by index ([`GossipState::seen_disk`]),
    /// so retransmissions are idempotent.
    pub disk_log: Vec<(usize, u64, u64)>,
    /// Per peer engine: how many entries of *their* disk log this
    /// engine has already absorbed.
    pub seen_disk: Vec<usize>,
    /// Merge counters, surfaced as [`metrics::GossipStats`].
    ///
    /// [`metrics::GossipStats`]: crate::metrics::GossipStats
    pub stats: GossipStats,
}

impl GossipState {
    /// Gossip bookkeeping for engine `engine_id` of `engines`, over a
    /// cluster of `nodes` remote nodes.
    pub fn new(engine_id: usize, engines: usize, nodes: usize) -> Self {
        assert!(engines >= 2, "gossip needs at least two engines");
        assert!(engine_id < engines, "engine id out of range");
        Self {
            engine_id,
            engines,
            round: 0,
            seen_round: vec![0; engines],
            node_versions: vec![0; nodes],
            counter: 0,
            disk_log: Vec::new(),
            seen_disk: vec![0; engines],
            stats: GossipStats::default(),
        }
    }

    /// Mint the next write epoch from this engine's interleaved stream:
    /// `counter * engines + engine_id + 1`. Epochs from distinct
    /// engines never collide (`(e - 1) % engines` recovers the minter),
    /// and a counter max-merged on every absorb keeps post-partition
    /// mints above everything this engine has *seen* — the same
    /// monotonicity the single-engine `next_epoch += 1` rule gives.
    pub fn mint_epoch(&mut self) -> u64 {
        let e = self.counter * self.engines as u64 + self.engine_id as u64 + 1;
        self.counter += 1;
        e
    }

    /// Lamport-style counter join on absorb.
    pub fn absorb_counter(&mut self, remote: u64) {
        self.counter = self.counter.max(remote);
    }
}

/// One full-state anti-entropy exchange unit. All vectors use
/// half-open `(start, end)` byte ranges, matching
/// `EpochMap::entries`. Reused across rounds via [`GossipDelta::clear`]
/// so steady-state export/absorb allocates nothing once the vectors
/// have grown to their working size.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct GossipDelta {
    /// Sending engine id.
    pub from: u32,
    /// Sender's export round (staleness filter per peer).
    pub round: u64,
    /// Sender's interleaved mint counter.
    pub epoch_counter: u64,
    /// Required floor: `(start, end, epoch)`.
    pub required: Vec<(u64, u64, u64)>,
    /// Applied vectors: `(node, start, end, epoch)`.
    pub applied: Vec<(u32, u64, u64, u64)>,
    /// Node states: `(node, version, state code)`.
    pub states: Vec<(u32, u64, u8)>,
    /// Missed-write ranges still owed to a node: `(node, start, len)`.
    pub missed: Vec<(u32, u64, u64)>,
    /// The sender's *cumulative* disk-surrender log, `(node, addr,
    /// len)` in append order; receivers consume past their cursor.
    pub surrendered: Vec<(u32, u64, u64)>,
}

impl GossipDelta {
    /// Empty the delta for reuse, keeping every vector's capacity.
    pub fn clear(&mut self) {
        self.from = 0;
        self.round = 0;
        self.epoch_counter = 0;
        self.required.clear();
        self.applied.clear();
        self.states.clear();
        self.missed.clear();
        self.surrendered.clear();
    }

    /// Serialize into `buf` (appended; little-endian throughout). The
    /// socket backend wraps this body in its length-prefixed frame.
    pub fn encode_into(&self, buf: &mut Vec<u8>) {
        buf.extend_from_slice(&self.from.to_le_bytes());
        buf.extend_from_slice(&self.round.to_le_bytes());
        buf.extend_from_slice(&self.epoch_counter.to_le_bytes());
        buf.extend_from_slice(&(self.required.len() as u32).to_le_bytes());
        for &(s, e, ep) in &self.required {
            buf.extend_from_slice(&s.to_le_bytes());
            buf.extend_from_slice(&e.to_le_bytes());
            buf.extend_from_slice(&ep.to_le_bytes());
        }
        buf.extend_from_slice(&(self.applied.len() as u32).to_le_bytes());
        for &(n, s, e, ep) in &self.applied {
            buf.extend_from_slice(&n.to_le_bytes());
            buf.extend_from_slice(&s.to_le_bytes());
            buf.extend_from_slice(&e.to_le_bytes());
            buf.extend_from_slice(&ep.to_le_bytes());
        }
        buf.extend_from_slice(&(self.states.len() as u32).to_le_bytes());
        for &(n, v, c) in &self.states {
            buf.extend_from_slice(&n.to_le_bytes());
            buf.extend_from_slice(&v.to_le_bytes());
            buf.push(c);
        }
        buf.extend_from_slice(&(self.missed.len() as u32).to_le_bytes());
        for &(n, a, l) in &self.missed {
            buf.extend_from_slice(&n.to_le_bytes());
            buf.extend_from_slice(&a.to_le_bytes());
            buf.extend_from_slice(&l.to_le_bytes());
        }
        buf.extend_from_slice(&(self.surrendered.len() as u32).to_le_bytes());
        for &(n, a, l) in &self.surrendered {
            buf.extend_from_slice(&n.to_le_bytes());
            buf.extend_from_slice(&a.to_le_bytes());
            buf.extend_from_slice(&l.to_le_bytes());
        }
    }

    /// Deserialize `bytes` into `self` (clearing first; vector capacity
    /// is reused). Rejects truncated input, trailing garbage and
    /// unknown node-state codes.
    pub fn decode_from(&mut self, bytes: &[u8]) -> Result<(), &'static str> {
        self.clear();
        let mut cur = Cursor { bytes, pos: 0 };
        self.from = cur.u32()?;
        self.round = cur.u64()?;
        self.epoch_counter = cur.u64()?;
        let n = cur.u32()? as usize;
        self.required.reserve(n);
        for _ in 0..n {
            self.required.push((cur.u64()?, cur.u64()?, cur.u64()?));
        }
        let n = cur.u32()? as usize;
        self.applied.reserve(n);
        for _ in 0..n {
            self.applied
                .push((cur.u32()?, cur.u64()?, cur.u64()?, cur.u64()?));
        }
        let n = cur.u32()? as usize;
        self.states.reserve(n);
        for _ in 0..n {
            let entry = (cur.u32()?, cur.u64()?, cur.u8()?);
            if state_from_code(entry.2).is_none() {
                return Err("gossip delta: unknown node-state code");
            }
            self.states.push(entry);
        }
        let n = cur.u32()? as usize;
        self.missed.reserve(n);
        for _ in 0..n {
            self.missed.push((cur.u32()?, cur.u64()?, cur.u64()?));
        }
        let n = cur.u32()? as usize;
        self.surrendered.reserve(n);
        for _ in 0..n {
            self.surrendered.push((cur.u32()?, cur.u64()?, cur.u64()?));
        }
        if cur.pos != bytes.len() {
            return Err("gossip delta: trailing bytes");
        }
        Ok(())
    }

    /// Convenience for tests and one-shot callers: decode into a fresh
    /// delta.
    pub fn decode(bytes: &[u8]) -> Result<Self, &'static str> {
        let mut d = Self::default();
        d.decode_from(bytes)?;
        Ok(d)
    }
}

/// Bounds-checked little-endian reader over a byte slice.
struct Cursor<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Cursor<'_> {
    fn take(&mut self, n: usize) -> Result<&[u8], &'static str> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.bytes.len())
            .ok_or("gossip delta: truncated")?;
        let s = &self.bytes[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, &'static str> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, &'static str> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, &'static str> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_delta() -> GossipDelta {
        GossipDelta {
            from: 1,
            round: 42,
            epoch_counter: 7,
            required: vec![(0, 4096, 3), (8192, 16384, 9)],
            applied: vec![(0, 0, 4096, 3), (2, 8192, 16384, 9)],
            states: vec![(0, 5, STATE_ALIVE), (1, 2, STATE_DEAD), (2, 9, STATE_RESYNCING)],
            missed: vec![(1, 4096, 8192)],
            surrendered: vec![(1, 1 << 20, 4096)],
        }
    }

    #[test]
    fn encode_decode_roundtrip() {
        let d = sample_delta();
        let mut buf = Vec::new();
        d.encode_into(&mut buf);
        assert_eq!(GossipDelta::decode(&buf).expect("decodes"), d);
        // empty delta roundtrips too
        let empty = GossipDelta::default();
        let mut buf = Vec::new();
        empty.encode_into(&mut buf);
        assert_eq!(GossipDelta::decode(&buf).expect("decodes"), empty);
    }

    #[test]
    fn decode_rejects_truncation_and_trailing_bytes() {
        let d = sample_delta();
        let mut buf = Vec::new();
        d.encode_into(&mut buf);
        for cut in [0, 1, 4, buf.len() / 2, buf.len() - 1] {
            assert!(
                GossipDelta::decode(&buf[..cut]).is_err(),
                "truncation at {cut} must fail"
            );
        }
        buf.push(0);
        assert!(GossipDelta::decode(&buf).is_err(), "trailing byte must fail");
    }

    #[test]
    fn decode_rejects_unknown_state_code() {
        let mut d = sample_delta();
        d.states.push((0, 1, 99));
        let mut buf = Vec::new();
        d.encode_into(&mut buf);
        assert!(GossipDelta::decode(&buf).is_err());
    }

    #[test]
    fn clear_keeps_vector_capacity() {
        let mut d = sample_delta();
        let caps = (
            d.required.capacity(),
            d.applied.capacity(),
            d.states.capacity(),
            d.missed.capacity(),
            d.surrendered.capacity(),
        );
        d.clear();
        assert_eq!(d, GossipDelta::default());
        assert!(d.required.capacity() >= caps.0);
        assert!(d.applied.capacity() >= caps.1);
        assert!(d.states.capacity() >= caps.2);
        assert!(d.missed.capacity() >= caps.3);
        assert!(d.surrendered.capacity() >= caps.4);
    }

    #[test]
    fn state_codes_roundtrip_and_order_by_severity() {
        for s in [NodeState::Alive, NodeState::Resyncing, NodeState::Dead] {
            assert_eq!(state_from_code(state_code(s)), Some(s));
        }
        assert!(state_code(NodeState::Alive) < state_code(NodeState::Resyncing));
        assert!(state_code(NodeState::Resyncing) < state_code(NodeState::Dead));
        assert_eq!(state_from_code(3), None);
    }

    #[test]
    fn interleaved_mints_never_collide_across_engines() {
        let mut a = GossipState::new(0, 2, 3);
        let mut b = GossipState::new(1, 2, 3);
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..100 {
            let ea = a.mint_epoch();
            let eb = b.mint_epoch();
            assert_eq!((ea - 1) % 2, 0, "engine 0 mints its own stream");
            assert_eq!((eb - 1) % 2, 1, "engine 1 mints its own stream");
            assert!(seen.insert(ea) && seen.insert(eb), "epochs are unique");
        }
    }

    #[test]
    fn counter_join_keeps_mints_above_everything_seen() {
        let mut a = GossipState::new(0, 2, 1);
        let mut b = GossipState::new(1, 2, 1);
        for _ in 0..10 {
            b.mint_epoch();
        }
        let high = b.mint_epoch();
        a.absorb_counter(b.counter);
        assert!(a.mint_epoch() > high, "post-join mints dominate absorbed history");
    }

    #[test]
    #[should_panic(expected = "at least two engines")]
    fn single_engine_gossip_is_rejected() {
        let _ = GossipState::new(0, 1, 1);
    }

    #[test]
    #[should_panic(expected = "engine id out of range")]
    fn out_of_range_engine_id_is_rejected() {
        let _ = GossipState::new(2, 2, 1);
    }
}
