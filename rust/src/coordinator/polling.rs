//! Work-Completion handling policies (paper §4.2, §5.2).
//!
//! Each policy is a pure state machine: the executor (simulated host or
//! live poller thread) asks it what to do next after each poll attempt.
//! This keeps the exact paper semantics testable in isolation:
//!
//! * **Busy** — spin forever, polling one WC at a time. Best latency, burns
//!   a core per CQ.
//! * **Event** — armed CQ; each interrupt context processes exactly one WC,
//!   then re-arms. No idle CPU, but one interrupt + context switch per WC.
//! * **EventBatch** — NAPI-style: per interrupt, poll up to `budget` WCs
//!   (K ≤ N in one context), then re-arm — late-arriving WCs need a fresh
//!   interrupt.
//! * **Adaptive** (the paper's contribution) — event-triggered; once woken,
//!   batch-poll and *keep retrying on empty polls* up to `max_retry` times
//!   before re-arming. Burst loads keep it in the polling loop (busy-like
//!   throughput); intermittent loads let it re-arm quickly (event-like CPU).
//! * **HybridTimer** — the X-RDMA-style [30] event↔busy switch with a fixed
//!   spin timer, included for the §4.2 ablation.
//!
//! SCQ(M) is a *topology* (M shared CQs with busy pollers), not a wake
//! policy — see [`PollingMode::Scq`] and the channel layer.

/// How completion handling is configured system-wide.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PollingMode {
    Busy,
    Event,
    EventBatch { budget: u32 },
    Adaptive { batch: u32, max_retry: u32 },
    HybridTimer { spin_ns: u64 },
    /// M shared CQs, `pollers` busy-polling threads per shared CQ.
    Scq { m: u32, pollers: u32 },
}

impl PollingMode {
    pub fn parse(s: &str) -> Result<Self, String> {
        // forms: busy | event | eventbatch[:N] | adaptive[:B,R] |
        //        hybrid:NS | scq[:M,P]
        let (head, arg) = match s.split_once(':') {
            Some((h, a)) => (h, Some(a)),
            None => (s, None),
        };
        match head {
            "busy" => Ok(Self::Busy),
            "event" => Ok(Self::Event),
            "eventbatch" => {
                let budget = arg.map(|a| a.parse().map_err(|_| "bad budget")).transpose()?;
                Ok(Self::EventBatch {
                    budget: budget.unwrap_or(16),
                })
            }
            "adaptive" => {
                let (batch, retry) = match arg {
                    None => (16, 120),
                    Some(a) => {
                        let (b, r) = a
                            .split_once(',')
                            .ok_or("adaptive:BATCH,RETRY")?;
                        (
                            b.parse().map_err(|_| "bad batch")?,
                            r.parse().map_err(|_| "bad retry")?,
                        )
                    }
                };
                Ok(Self::Adaptive {
                    batch,
                    max_retry: retry,
                })
            }
            "hybrid" => {
                let ns = arg.ok_or("hybrid:SPIN_NS")?.parse().map_err(|_| "bad ns")?;
                Ok(Self::HybridTimer { spin_ns: ns })
            }
            "scq" => {
                let (m, p) = match arg {
                    None => (1, 1),
                    Some(a) => {
                        let (m, p) = a.split_once(',').ok_or("scq:M,POLLERS")?;
                        (
                            m.parse().map_err(|_| "bad M")?,
                            p.parse().map_err(|_| "bad pollers")?,
                        )
                    }
                };
                Ok(Self::Scq { m, pollers: p })
            }
            other => Err(format!("unknown polling mode `{other}`")),
        }
    }

    /// Does this mode use CQ event notification (interrupts)?
    pub fn event_driven(&self) -> bool {
        matches!(
            self,
            Self::Event | Self::EventBatch { .. } | Self::Adaptive { .. } | Self::HybridTimer { .. }
        )
    }

    /// Short display name used by figure legends.
    pub fn label(&self) -> String {
        match self {
            Self::Busy => "Busy".into(),
            Self::Event => "Event".into(),
            Self::EventBatch { .. } => "EventBatch".into(),
            Self::Adaptive { max_retry, .. } => format!("AdaptivePoll(r={max_retry})"),
            Self::HybridTimer { .. } => "HybridTimer".into(),
            Self::Scq { m, pollers } => format!("SCQ({m})x{pollers}"),
        }
    }
}

/// What the executor should do next.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PollStep {
    /// Call poll_cq again, taking up to `max` WCs.
    Poll { max: u32 },
    /// Re-arm CQ notification and go to sleep until the next interrupt.
    Rearm,
}

/// Per-poller policy state machine. Create one per poller thread; call
/// [`PollerFsm::on_wake`] when the thread wakes (interrupt or spin start),
/// then alternate `poll_cq` with [`PollerFsm::after_poll`] until it says
/// [`PollStep::Rearm`] (busy/SCQ never do).
#[derive(Debug, Clone)]
pub struct PollerFsm {
    mode: PollingMode,
    retries_left: u32,
    budget_left: u32,
    spin_deadline_ns: u64,
}

impl PollerFsm {
    pub fn new(mode: PollingMode) -> Self {
        Self {
            mode,
            retries_left: 0,
            budget_left: 0,
            spin_deadline_ns: 0,
        }
    }

    pub fn mode(&self) -> PollingMode {
        self.mode
    }

    /// Remaining empty-poll retries before this poller re-arms (Adaptive).
    /// Executors use this to compute how long an idle spin may last.
    pub fn retries_left(&self) -> u32 {
        self.retries_left
    }

    /// Absolute spin deadline (HybridTimer).
    pub fn spin_deadline_ns(&self) -> u64 {
        self.spin_deadline_ns
    }

    /// The poller woke up (event delivery for event-driven modes; thread
    /// start for busy/SCQ). Returns the first step.
    pub fn on_wake(&mut self, now_ns: u64) -> PollStep {
        match self.mode {
            PollingMode::Busy | PollingMode::Scq { .. } => PollStep::Poll { max: 1 },
            PollingMode::Event => PollStep::Poll { max: 1 },
            PollingMode::EventBatch { budget } => {
                self.budget_left = budget;
                PollStep::Poll { max: budget }
            }
            PollingMode::Adaptive { batch, max_retry } => {
                self.retries_left = max_retry;
                PollStep::Poll { max: batch }
            }
            PollingMode::HybridTimer { spin_ns } => {
                self.spin_deadline_ns = now_ns + spin_ns;
                PollStep::Poll { max: 1 }
            }
        }
    }

    /// A poll_cq call returned `got` WCs at time `now_ns`; decide the next
    /// step.
    pub fn after_poll(&mut self, got: u32, now_ns: u64) -> PollStep {
        match self.mode {
            // Busy polling never sleeps; one WC at a time (paper §4.2).
            PollingMode::Busy | PollingMode::Scq { .. } => PollStep::Poll { max: 1 },

            // Event mode: exactly one WC per interrupt context.
            PollingMode::Event => PollStep::Rearm,

            // Event batch: one batched poll per interrupt. If it got a full
            // batch there may be more — NAPI re-polls until short read, but
            // the paper's Event batch returns to event mode after its
            // budget; model that: rearm once the budget poll happened.
            PollingMode::EventBatch { .. } => PollStep::Rearm,

            PollingMode::Adaptive { batch, max_retry } => {
                if got > 0 {
                    // success: keep draining, reset the retry budget.
                    self.retries_left = max_retry;
                    PollStep::Poll { max: batch }
                } else if self.retries_left > 0 {
                    self.retries_left -= 1;
                    PollStep::Poll { max: batch }
                } else {
                    PollStep::Rearm
                }
            }

            PollingMode::HybridTimer { .. } => {
                if now_ns < self.spin_deadline_ns {
                    PollStep::Poll { max: 1 }
                } else {
                    PollStep::Rearm
                }
            }
        }
    }
}

/// When a re-armed (sleeping) poller may wake at the latest: the
/// interrupt horizon `now + max_sleep_ns`, clamped to the engine's
/// next armed WR deadline so a lost completion is still detected on
/// time ([`IoEngine::next_timer_at`] supplies `next_deadline_ns`,
/// `u64::MAX` when deadlines are off or nothing is outstanding).
/// Returns an absolute wake time that is never in the past — an
/// already-overdue deadline wakes the poller immediately.
///
/// [`IoEngine::next_timer_at`]: crate::coordinator::engine::IoEngine::next_timer_at
pub fn clamp_wake_ns(now_ns: u64, next_deadline_ns: u64, max_sleep_ns: u64) -> u64 {
    let horizon = now_ns.saturating_add(max_sleep_ns);
    horizon.min(next_deadline_ns.max(now_ns))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trips() {
        assert_eq!(PollingMode::parse("busy").unwrap(), PollingMode::Busy);
        assert_eq!(PollingMode::parse("event").unwrap(), PollingMode::Event);
        assert_eq!(
            PollingMode::parse("eventbatch:8").unwrap(),
            PollingMode::EventBatch { budget: 8 }
        );
        assert_eq!(
            PollingMode::parse("adaptive:16,120").unwrap(),
            PollingMode::Adaptive {
                batch: 16,
                max_retry: 120
            }
        );
        assert_eq!(
            PollingMode::parse("scq:2,3").unwrap(),
            PollingMode::Scq { m: 2, pollers: 3 }
        );
        assert_eq!(
            PollingMode::parse("hybrid:5000").unwrap(),
            PollingMode::HybridTimer { spin_ns: 5000 }
        );
        assert!(PollingMode::parse("wat").is_err());
    }

    #[test]
    fn busy_never_rearms() {
        let mut f = PollerFsm::new(PollingMode::Busy);
        assert_eq!(f.on_wake(0), PollStep::Poll { max: 1 });
        for i in 0..1000 {
            assert_eq!(f.after_poll(0, i), PollStep::Poll { max: 1 });
        }
    }

    #[test]
    fn event_handles_one_wc_per_interrupt() {
        let mut f = PollerFsm::new(PollingMode::Event);
        assert_eq!(f.on_wake(0), PollStep::Poll { max: 1 });
        assert_eq!(f.after_poll(1, 10), PollStep::Rearm);
        // even an empty poll (spurious interrupt) re-arms
        assert_eq!(f.on_wake(20), PollStep::Poll { max: 1 });
        assert_eq!(f.after_poll(0, 30), PollStep::Rearm);
    }

    #[test]
    fn eventbatch_single_budgeted_poll() {
        let mut f = PollerFsm::new(PollingMode::EventBatch { budget: 16 });
        assert_eq!(f.on_wake(0), PollStep::Poll { max: 16 });
        // got K<=N, then back to event mode — late WCs need a new interrupt
        assert_eq!(f.after_poll(7, 10), PollStep::Rearm);
    }

    #[test]
    fn adaptive_drains_bursts() {
        let mut f = PollerFsm::new(PollingMode::Adaptive {
            batch: 4,
            max_retry: 3,
        });
        assert_eq!(f.on_wake(0), PollStep::Poll { max: 4 });
        // burst: keeps polling as long as WCs arrive
        for i in 0..100 {
            assert_eq!(f.after_poll(4, i), PollStep::Poll { max: 4 });
        }
        // then 3 empty retries, then rearm
        assert_eq!(f.after_poll(0, 200), PollStep::Poll { max: 4 });
        assert_eq!(f.after_poll(0, 201), PollStep::Poll { max: 4 });
        assert_eq!(f.after_poll(0, 202), PollStep::Poll { max: 4 });
        assert_eq!(f.after_poll(0, 203), PollStep::Rearm);
    }

    #[test]
    fn adaptive_success_resets_retry_budget() {
        let mut f = PollerFsm::new(PollingMode::Adaptive {
            batch: 1,
            max_retry: 2,
        });
        f.on_wake(0);
        assert_eq!(f.after_poll(0, 1), PollStep::Poll { max: 1 }); // retry 1
        assert_eq!(f.after_poll(1, 2), PollStep::Poll { max: 1 }); // success resets
        assert_eq!(f.after_poll(0, 3), PollStep::Poll { max: 1 }); // retry 1 again
        assert_eq!(f.after_poll(0, 4), PollStep::Poll { max: 1 }); // retry 2
        assert_eq!(f.after_poll(0, 5), PollStep::Rearm);
    }

    #[test]
    fn adaptive_zero_retry_behaves_like_eventbatch() {
        let mut f = PollerFsm::new(PollingMode::Adaptive {
            batch: 8,
            max_retry: 0,
        });
        assert_eq!(f.on_wake(0), PollStep::Poll { max: 8 });
        assert_eq!(f.after_poll(0, 1), PollStep::Rearm);
    }

    #[test]
    fn hybrid_spins_until_deadline() {
        let mut f = PollerFsm::new(PollingMode::HybridTimer { spin_ns: 100 });
        assert_eq!(f.on_wake(1000), PollStep::Poll { max: 1 });
        assert_eq!(f.after_poll(0, 1050), PollStep::Poll { max: 1 });
        assert_eq!(f.after_poll(1, 1099), PollStep::Poll { max: 1 });
        assert_eq!(f.after_poll(0, 1100), PollStep::Rearm);
    }

    /// Satellite property: for any interleaving of `on_wake`/`after_poll`
    /// the HybridTimer FSM never decides to poll at or past its spin
    /// deadline — an empty poll there always re-arms — and every wake
    /// resets the deadline.
    #[test]
    fn prop_hybrid_never_polls_past_deadline() {
        use crate::util::prop::{self, cfg};
        prop::forall(cfg(0x4B1D), |rng, size| {
            let spin = 1 + rng.gen_below(100_000);
            let mut f = PollerFsm::new(PollingMode::HybridTimer { spin_ns: spin });
            let mut now = rng.gen_below(1 << 30);
            let mut step = f.on_wake(now);
            if f.spin_deadline_ns() != now + spin {
                return Err("wake must arm the spin deadline".into());
            }
            for _ in 0..size * 8 {
                match step {
                    PollStep::Poll { .. } => {
                        let got = if rng.gen_bool(0.4) { 1 } else { 0 };
                        now += rng.gen_below(spin + spin / 2) + 1;
                        step = f.after_poll(got, now);
                        if matches!(step, PollStep::Poll { .. }) && now >= f.spin_deadline_ns() {
                            return Err(format!(
                                "kept spinning at {now}, past deadline {}",
                                f.spin_deadline_ns()
                            ));
                        }
                        if matches!(step, PollStep::Rearm) && now < f.spin_deadline_ns() {
                            return Err("re-armed before the spin deadline".into());
                        }
                    }
                    PollStep::Rearm => {
                        now += 1 + rng.gen_below(100_000);
                        step = f.on_wake(now);
                        if f.spin_deadline_ns() != now + spin {
                            return Err("re-wake must reset the spin deadline".into());
                        }
                    }
                }
            }
            Ok(())
        });
    }

    /// Satellite property: for any interleaving, Adaptive polls at most
    /// `max_retry` extra times on an empty CQ before re-arming, always
    /// re-arms on retry exhaustion, and a non-empty poll refills the
    /// whole retry budget.
    #[test]
    fn prop_adaptive_bounds_empty_spins() {
        use crate::util::prop::{self, cfg};
        prop::forall(cfg(0xADA9), |rng, size| {
            let batch = 1 + rng.gen_below(16) as u32;
            let max_retry = rng.gen_below(24) as u32;
            let mut f = PollerFsm::new(PollingMode::Adaptive { batch, max_retry });
            let mut step = f.on_wake(0);
            let mut empty_streak = 0u32;
            let mut t = 0u64;
            for _ in 0..size * 8 {
                t += 1;
                match step {
                    PollStep::Poll { max } => {
                        if max != batch {
                            return Err(format!("poll budget {max} != batch {batch}"));
                        }
                        let got = if rng.gen_bool(0.5) {
                            0
                        } else {
                            1 + rng.gen_below(u64::from(max)) as u32
                        };
                        empty_streak = if got == 0 { empty_streak + 1 } else { 0 };
                        step = f.after_poll(got, t);
                        if matches!(step, PollStep::Poll { .. }) && empty_streak > max_retry {
                            return Err(format!(
                                "still spinning after {empty_streak} empty polls \
                                 (max_retry {max_retry})"
                            ));
                        }
                        if matches!(step, PollStep::Rearm) && empty_streak <= max_retry {
                            return Err(format!(
                                "re-armed after only {empty_streak} empty polls \
                                 with {max_retry} retries allowed"
                            ));
                        }
                    }
                    PollStep::Rearm => {
                        empty_streak = 0;
                        step = f.on_wake(t);
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn wake_clamp_never_sleeps_past_an_armed_deadline() {
        // no deadline armed: the full interrupt horizon
        assert_eq!(clamp_wake_ns(1_000, u64::MAX, 500), 1_500);
        // a deadline inside the horizon clamps the sleep
        assert_eq!(clamp_wake_ns(1_000, 1_200, 500), 1_200);
        // a deadline past the horizon leaves it alone
        assert_eq!(clamp_wake_ns(1_000, 9_000, 500), 1_500);
        // an overdue deadline wakes immediately, never in the past
        assert_eq!(clamp_wake_ns(1_000, 400, 500), 1_000);
        // saturates instead of wrapping near the clock's end
        assert_eq!(clamp_wake_ns(u64::MAX - 10, u64::MAX, 500), u64::MAX);
    }

    /// Satellite property: the clamped wake time is always within
    /// `[now, now + max_sleep]` and never past a future armed deadline.
    #[test]
    fn prop_wake_clamp_bounds() {
        use crate::util::prop::{self, cfg};
        prop::forall(cfg(0xC1A4), |rng, _size| {
            let now = rng.gen_below(1 << 40);
            let dl = if rng.gen_bool(0.2) {
                u64::MAX
            } else {
                rng.gen_below(1 << 41)
            };
            let max_sleep = rng.gen_below(1 << 20);
            let wake = clamp_wake_ns(now, dl, max_sleep);
            if wake < now {
                return Err(format!("woke in the past: {wake} < {now}"));
            }
            if wake > now.saturating_add(max_sleep) {
                return Err(format!("slept past the horizon: {wake}"));
            }
            if dl != u64::MAX && dl >= now && wake > dl {
                return Err(format!("slept past the armed deadline: {wake} > {dl}"));
            }
            Ok(())
        });
    }

    #[test]
    fn labels_for_legends() {
        assert_eq!(PollingMode::Busy.label(), "Busy");
        assert_eq!(
            PollingMode::Scq { m: 2, pollers: 1 }.label(),
            "SCQ(2)x1"
        );
        assert!(PollingMode::Adaptive {
            batch: 16,
            max_retry: 120
        }
        .label()
        .contains("120"));
    }

    #[test]
    fn event_driven_classification() {
        assert!(!PollingMode::Busy.event_driven());
        assert!(!PollingMode::Scq { m: 1, pollers: 1 }.event_driven());
        assert!(PollingMode::Event.event_driven());
        assert!(PollingMode::Adaptive {
            batch: 1,
            max_retry: 1
        }
        .event_driven());
    }
}
