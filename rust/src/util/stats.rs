//! Small statistics helpers shared by benches and experiment harnesses.

/// Online mean/variance (Welford).
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Exponentially weighted moving average (regulator hooks, load tracking).
#[derive(Debug, Clone)]
pub struct Ewma {
    alpha: f64,
    value: Option<f64>,
}

impl Ewma {
    pub fn new(alpha: f64) -> Self {
        assert!((0.0..=1.0).contains(&alpha));
        Self { alpha, value: None }
    }

    #[inline]
    pub fn update(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(v) => v + self.alpha * (x - v),
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

/// Percentile of a sorted slice (nearest-rank).
pub fn percentile_sorted(sorted: &[u64], q: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let idx = ((q.clamp(0.0, 1.0) * sorted.len() as f64).ceil() as usize).max(1) - 1;
    sorted[idx.min(sorted.len() - 1)]
}

pub fn mean_u64(xs: &[u64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().map(|&x| x as f64).sum::<f64>() / xs.len() as f64
}

/// Geometric mean of ratios (used for summary speedup lines).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_naive() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.add(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (xs.len() - 1) as f64;
        assert!((w.mean() - mean).abs() < 1e-12);
        assert!((w.variance() - var).abs() < 1e-12);
    }

    #[test]
    fn welford_single_sample() {
        let mut w = Welford::new();
        w.add(5.0);
        assert_eq!(w.mean(), 5.0);
        assert_eq!(w.variance(), 0.0);
    }

    #[test]
    fn ewma_converges() {
        let mut e = Ewma::new(0.5);
        assert!(e.get().is_none());
        for _ in 0..32 {
            e.update(10.0);
        }
        assert!((e.get().unwrap() - 10.0).abs() < 1e-6);
    }

    #[test]
    fn ewma_first_value_unsmoothed() {
        let mut e = Ewma::new(0.1);
        assert_eq!(e.update(7.0), 7.0);
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs: Vec<u64> = (1..=100).collect();
        assert_eq!(percentile_sorted(&xs, 0.50), 50);
        assert_eq!(percentile_sorted(&xs, 0.99), 99);
        assert_eq!(percentile_sorted(&xs, 1.0), 100);
        assert_eq!(percentile_sorted(&[], 0.5), 0);
    }

    #[test]
    fn geomean_of_ratios() {
        let g = geomean(&[2.0, 8.0]);
        assert!((g - 4.0).abs() < 1e-12);
        assert_eq!(geomean(&[]), 0.0);
    }
}
