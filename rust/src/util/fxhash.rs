//! Fast non-cryptographic hasher for the simulator's hot-path maps
//! (io-id → AppIo, wr-id → post time, page → frame). The std `HashMap`
//! default (SipHash-1-3) costs ~3× more per lookup than this FxHash-style
//! multiply-rotate, and these maps sit on every simulated I/O's path —
//! see EXPERIMENTS.md §Perf for the before/after.

use std::collections::HashMap;
use std::hash::{BuildHasherDefault, Hasher};

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

#[derive(Default)]
pub struct FxHasher {
    hash: u64,
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.write_u8(b);
        }
    }

    #[inline]
    fn write_u8(&mut self, n: u8) {
        self.add(n as u64);
    }

    #[inline]
    fn write_u32(&mut self, n: u32) {
        self.add(n as u64);
    }

    #[inline]
    fn write_u64(&mut self, n: u64) {
        self.add(n);
    }

    #[inline]
    fn write_usize(&mut self, n: usize) {
        self.add(n as u64);
    }
}

impl FxHasher {
    #[inline]
    fn add(&mut self, w: u64) {
        self.hash = (self.hash.rotate_left(5) ^ w).wrapping_mul(SEED);
    }
}

pub type FxBuildHasher = BuildHasherDefault<FxHasher>;
pub type FxHashMap<K, V> = HashMap<K, V, FxBuildHasher>;

pub fn fx_map<K, V>() -> FxHashMap<K, V> {
    FxHashMap::default()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_roundtrip() {
        let mut m: FxHashMap<u64, u64> = fx_map();
        for i in 0..10_000u64 {
            m.insert(i, i * 3);
        }
        for i in 0..10_000u64 {
            assert_eq!(m.get(&i), Some(&(i * 3)));
        }
        assert_eq!(m.len(), 10_000);
    }

    #[test]
    fn sequential_keys_spread() {
        use std::hash::BuildHasher;
        let bh = FxBuildHasher::default();
        // sequential u64 keys must not collide in the low bits (the map's
        // bucket index) — check a crude spread over 256 buckets
        let mut buckets = [0u32; 256];
        for i in 0..4096u64 {
            let h = bh.hash_one(i);
            buckets[(h & 0xff) as usize] += 1;
        }
        let max = *buckets.iter().max().unwrap();
        assert!(max < 64, "bucket skew: {max}");
    }
}
