//! Minimal property-based testing driver (proptest is not in the offline
//! registry). Runs a property over many seeded random cases and, on failure,
//! retries with progressively "smaller" generator budgets to report a
//! near-minimal case, then panics with the reproducing seed.

use super::rng::Pcg32;

/// Configuration for a property run.
#[derive(Clone, Copy)]
pub struct PropCfg {
    pub cases: u32,
    pub seed: u64,
    /// Upper bound passed to the property as a size hint; shrink attempts
    /// re-run failing seeds with smaller sizes.
    pub max_size: usize,
}

impl Default for PropCfg {
    fn default() -> Self {
        Self {
            cases: 256,
            seed: 0x5D_B0C5, // tests usually pin their own seed via `cfg()`
            max_size: 64,
        }
    }
}

/// `forall(cfg, |rng, size| -> Result<(), String>)`
pub fn forall<F>(cfg: PropCfg, mut prop: F)
where
    F: FnMut(&mut Pcg32, usize) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let case_seed = cfg.seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Pcg32::new(case_seed);
        let size = 1 + (rng.gen_below(cfg.max_size as u64) as usize);
        if let Err(msg) = prop(&mut rng, size) {
            // Shrink: retry the same seed with smaller sizes to find the
            // smallest size that still fails.
            let mut min_fail = (size, msg.clone());
            for s in 1..size {
                let mut r2 = Pcg32::new(case_seed);
                if let Err(m) = prop(&mut r2, s) {
                    min_fail = (s, m);
                    break;
                }
            }
            panic!(
                "property failed (seed={case_seed:#x}, case={case}, size={}): {}",
                min_fail.0, min_fail.1
            );
        }
    }
}

/// Convenience: default config with an explicit seed (tests pin seeds so CI
/// is deterministic).
pub fn cfg(seed: u64) -> PropCfg {
    PropCfg {
        cases: 256,
        seed,
        max_size: 64,
    }
}

/// Generate a random vector of u64 in [0, bound).
pub fn vec_u64(rng: &mut Pcg32, len: usize, bound: u64) -> Vec<u64> {
    (0..len).map(|_| rng.gen_below(bound)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall(cfg(1), |rng, size| {
            let v = vec_u64(rng, size, 100);
            if v.len() == size {
                Ok(())
            } else {
                Err("len mismatch".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        forall(cfg(2), |_rng, size| {
            if size < 1000 {
                Err("always fails".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn shrink_reports_small_size() {
        let r = std::panic::catch_unwind(|| {
            forall(cfg(3), |_rng, size| {
                if size >= 2 {
                    Err("fails at >=2".into())
                } else {
                    Ok(())
                }
            });
        });
        let err = r.unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("size=2"), "msg: {msg}");
    }
}
