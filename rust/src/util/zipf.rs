//! Zipfian distribution generator, YCSB-style.
//!
//! The paper drives VoltDB/MongoDB/Redis with YCSB using a Zipfian request
//! distribution over 10M records (Facebook ETC/SYS workloads). This is the
//! same incremental-zeta generator YCSB uses (Gray et al., "Quickly
//! generating billion-record synthetic databases"), so hot-set skew matches.

use super::rng::Pcg32;

/// Zipfian generator over `[0, n)` with skew `theta` (YCSB default 0.99).
#[derive(Debug, Clone)]
pub struct Zipfian {
    n: u64,
    theta: f64,
    alpha: f64,
    zetan: f64,
    eta: f64,
    zeta2: f64,
}

impl Zipfian {
    pub fn new(n: u64, theta: f64) -> Self {
        assert!(n > 0, "zipfian over empty domain");
        // theta = 1.0 is the harmonic boundary: alpha = 1/(1-theta) blows
        // up and the large-n zeta integral divides by a = 1-theta = 0, so
        // every sample collapses to garbage instead of a Zipf(1) draw.
        // YCSB's generator has the same open-interval domain.
        assert!(
            (0.0..1.0).contains(&theta),
            "zipfian skew theta must lie in [0, 1): theta = 1 is the \
             harmonic boundary (alpha and the zeta integral diverge); got {theta}"
        );
        let zetan = Self::zeta(n, theta);
        let zeta2 = Self::zeta(2, theta);
        let alpha = 1.0 / (1.0 - theta);
        let eta = (1.0 - (2.0 / n as f64).powf(1.0 - theta)) / (1.0 - zeta2 / zetan);
        Self {
            n,
            theta,
            alpha,
            zetan,
            eta,
            zeta2,
        }
    }

    /// YCSB default skew.
    pub fn ycsb(n: u64) -> Self {
        Self::new(n, 0.99)
    }

    fn zeta(n: u64, theta: f64) -> f64 {
        // Direct sum for small n; integral approximation for large n keeps
        // construction O(1)-ish without materially changing the skew.
        if n <= 1_000_000 {
            (1..=n).map(|i| 1.0 / (i as f64).powf(theta)).sum()
        } else {
            let head: f64 = (1..=1_000_000u64)
                .map(|i| 1.0 / (i as f64).powf(theta))
                .sum();
            // integral of x^-theta from 1e6 to n
            let a = 1.0 - theta;
            head + ((n as f64).powf(a) - 1_000_000f64.powf(a)) / a
        }
    }

    /// Sample a rank in `[0, n)`; rank 0 is the hottest item.
    #[inline]
    pub fn sample(&self, rng: &mut Pcg32) -> u64 {
        let u = rng.gen_f64();
        let uz = u * self.zetan;
        if uz < 1.0 {
            return 0;
        }
        if uz < 1.0 + 0.5f64.powf(self.theta) {
            return 1;
        }
        let v = ((self.eta * u) - self.eta + 1.0).powf(self.alpha);
        let item = (self.n as f64 * v) as u64;
        item.min(self.n - 1)
    }

    pub fn n(&self) -> u64 {
        self.n
    }

    pub fn theta(&self) -> f64 {
        self.theta
    }

    /// Fraction of probability mass carried by the hottest `k` items
    /// (analytic, used to size resident sets in the app models).
    pub fn mass_of_top(&self, k: u64) -> f64 {
        Self::zeta(k.min(self.n).max(1), self.theta) / self.zetan
    }

    #[allow(dead_code)]
    fn zeta2(&self) -> f64 {
        self.zeta2
    }
}

/// Scrambled Zipfian: spreads the hot ranks over the key space with a
/// multiplicative hash, as YCSB's `ScrambledZipfianGenerator` does, so hot
/// keys are not physically adjacent (matters for merge-adjacency realism).
#[derive(Debug, Clone)]
pub struct ScrambledZipfian {
    inner: Zipfian,
}

impl ScrambledZipfian {
    pub fn new(n: u64, theta: f64) -> Self {
        Self {
            inner: Zipfian::new(n, theta),
        }
    }

    #[inline]
    pub fn sample(&self, rng: &mut Pcg32) -> u64 {
        let rank = self.inner.sample(rng);
        fnv1a64(rank) % self.inner.n()
    }

    pub fn n(&self) -> u64 {
        self.inner.n()
    }

    /// Recover the underlying rank→key mapping (tests / resident-set setup).
    pub fn key_for_rank(&self, rank: u64) -> u64 {
        fnv1a64(rank) % self.inner.n()
    }
}

#[inline]
pub fn fnv1a64(x: u64) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for i in 0..8 {
        h ^= (x >> (i * 8)) & 0xff;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samples_in_range() {
        let z = Zipfian::ycsb(1000);
        let mut rng = Pcg32::new(1);
        for _ in 0..10_000 {
            assert!(z.sample(&mut rng) < 1000);
        }
    }

    #[test]
    fn rank_zero_is_hottest() {
        let z = Zipfian::ycsb(10_000);
        let mut rng = Pcg32::new(2);
        let mut counts = vec![0u32; 10_000];
        for _ in 0..100_000 {
            counts[z.sample(&mut rng) as usize] += 1;
        }
        let c0 = counts[0];
        // hottest item should dominate e.g. the item at rank 100
        assert!(c0 > counts[100] * 3, "c0={} c100={}", c0, counts[100]);
        // and carry several percent of total mass at theta=0.99
        assert!(c0 as f64 / 100_000.0 > 0.03);
    }

    #[test]
    fn skew_matches_analytic_top_mass() {
        let z = Zipfian::ycsb(100_000);
        let mut rng = Pcg32::new(3);
        let n = 200_000;
        let k = 1000;
        let hits = (0..n).filter(|_| z.sample(&mut rng) < k).count();
        let frac = hits as f64 / n as f64;
        let analytic = z.mass_of_top(k);
        assert!(
            (frac - analytic).abs() < 0.03,
            "measured {frac} analytic {analytic}"
        );
    }

    #[test]
    fn scrambled_spreads_hot_keys() {
        let z = ScrambledZipfian::new(1_000_000, 0.99);
        let k0 = z.key_for_rank(0);
        let k1 = z.key_for_rank(1);
        assert_ne!(k0, k1);
        // hot keys should not be adjacent after scrambling
        assert!(k0.abs_diff(k1) > 1000);
    }

    #[test]
    fn large_domain_constructs_fast_and_samples() {
        let z = Zipfian::ycsb(1_000_000_000);
        let mut rng = Pcg32::new(4);
        for _ in 0..1000 {
            assert!(z.sample(&mut rng) < 1_000_000_000);
        }
    }

    /// Regression (ISSUE 9): the old guard
    /// `(0.0..1.0).contains(&theta) || theta > 0.0` was vacuous — any
    /// positive theta passed, including exactly 1.0, which yields
    /// `alpha = inf` and a zero divisor in the large-n zeta path.
    #[test]
    #[should_panic(expected = "harmonic boundary")]
    fn theta_one_is_rejected_cleanly() {
        let _ = Zipfian::new(2_000_000, 1.0);
    }

    #[test]
    #[should_panic(expected = "harmonic boundary")]
    fn theta_above_one_is_rejected_cleanly() {
        let _ = Zipfian::new(1000, 1.5);
    }

    /// theta = 0.99 over n > 1_000_000 exercises the integral zeta
    /// approximation with `a = 1 - theta` close to zero: every derived
    /// constant and every sample must stay finite and in range.
    #[test]
    fn near_boundary_theta_over_large_domain_is_finite() {
        let n = 2_000_000;
        let z = Zipfian::new(n, 0.99);
        assert!(z.zetan.is_finite() && z.zetan > 0.0);
        assert!(z.alpha.is_finite());
        assert!(z.eta.is_finite());
        let mut rng = Pcg32::new(9);
        for _ in 0..10_000 {
            let s = z.sample(&mut rng);
            assert!(s < n, "sample {s} out of range");
        }
    }

    #[test]
    fn mass_of_top_monotone() {
        let z = Zipfian::ycsb(10_000);
        let m10 = z.mass_of_top(10);
        let m100 = z.mass_of_top(100);
        let m_all = z.mass_of_top(10_000);
        assert!(m10 < m100 && m100 < m_all);
        assert!((m_all - 1.0).abs() < 1e-9);
    }
}
