//! Human-readable formatting for bytes, rates, durations, counts.

pub fn bytes(v: u64) -> String {
    bytes_f(v as f64)
}

pub fn bytes_f(v: f64) -> String {
    const UNITS: [&str; 6] = ["B", "KB", "MB", "GB", "TB", "PB"];
    let mut x = v;
    let mut i = 0;
    while x >= 1024.0 && i + 1 < UNITS.len() {
        x /= 1024.0;
        i += 1;
    }
    if i == 0 {
        format!("{:.0}{}", x, UNITS[i])
    } else {
        format!("{:.2}{}", x, UNITS[i])
    }
}

/// Bytes/second.
pub fn rate(bytes_per_sec: f64) -> String {
    format!("{}/s", bytes_f(bytes_per_sec))
}

/// Nanoseconds, auto-scaled.
pub fn dur_ns(ns: u64) -> String {
    dur_ns_f(ns as f64)
}

pub fn dur_ns_f(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0}ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2}us", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2}ms", ns / 1_000_000.0)
    } else {
        format!("{:.2}s", ns / 1_000_000_000.0)
    }
}

/// Large counts: 13_200_000 -> "13.2M".
pub fn count(v: u64) -> String {
    let x = v as f64;
    if x >= 1e9 {
        format!("{:.2}G", x / 1e9)
    } else if x >= 1e6 {
        format!("{:.1}M", x / 1e6)
    } else if x >= 1e3 {
        format!("{:.0}K", x / 1e3)
    } else {
        format!("{v}")
    }
}

/// Ops/sec with auto-scaling.
pub fn ops(v: f64) -> String {
    if v >= 1e6 {
        format!("{:.2}Mops/s", v / 1e6)
    } else if v >= 1e3 {
        format!("{:.1}Kops/s", v / 1e3)
    } else {
        format!("{v:.1}ops/s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_scales() {
        assert_eq!(bytes(512), "512B");
        assert_eq!(bytes(2048), "2.00KB");
        assert_eq!(bytes(7 * 1024 * 1024), "7.00MB");
        assert_eq!(bytes(3 * 1024 * 1024 * 1024), "3.00GB");
    }

    #[test]
    fn durations_scale() {
        assert_eq!(dur_ns(500), "500ns");
        assert_eq!(dur_ns(1500), "1.50us");
        assert_eq!(dur_ns(2_500_000), "2.50ms");
        assert_eq!(dur_ns(3_000_000_000), "3.00s");
    }

    #[test]
    fn counts_scale() {
        assert_eq!(count(999), "999");
        assert_eq!(count(13_200_000), "13.2M");
        assert_eq!(count(308_000), "308K");
        assert_eq!(count(2_500_000_000), "2.50G");
    }

    #[test]
    fn ops_scale() {
        assert_eq!(ops(1_500_000.0), "1.50Mops/s");
        assert_eq!(ops(2_500.0), "2.5Kops/s");
    }
}
