//! Shared utilities: deterministic RNGs, Zipfian generators, histograms,
//! statistics, property-test driver, the shared virtual-time event
//! queue, and human-readable formatting.

pub mod eventq;
pub mod fmt;
pub mod fxhash;
pub mod hist;
pub mod idlist;
pub mod prop;
pub mod rng;
pub mod slab;
pub mod stats;
pub mod zipf;
