//! Generational slab: the engine's zero-allocation in-flight ledgers.
//!
//! The `IoEngine` mints every id it later looks up — sub-I/O ids, WR ids,
//! leg-aggregation handles — so instead of hashing those ids into
//! `FxHashMap`s on every submit/retire, the slab *encodes the storage
//! location into the id itself*: a key is `generation << 32 | slot`, and a
//! lookup is one bounds check, one generation compare, and one array
//! index. Backends keep treating the ids as opaque `u64`s.
//!
//! The **generation** is what makes recycled slots safe under a chaotic
//! completion queue: when a slot is freed its generation is bumped, so a
//! stale id held by a late or duplicate work completion can never resolve
//! to the slot's next occupant — `get`/`remove` with an old-generation key
//! return `None`, exactly like a missing hash-map entry, and the engine
//! counts it as a duplicate. Generations are 31 bits (bit 63 of a key is
//! never set, keeping slab keys clear of the engine's reserved id space
//! and of the `u64::MAX` resync sentinel), so a single slot must be
//! reused 2^31 times before a generation repeats — at which point the
//! colliding WR would also need to have been in flight across the entire
//! wrap, which the admission window makes impossible.
//!
//! Steady state allocates nothing: `insert` pops the free list, `remove`
//! pushes it back, and both `Vec`s keep their high-water capacity.

/// A generational slab keyed by self-describing `u64` ids.
#[derive(Debug)]
pub struct Slab<T> {
    slots: Vec<Entry<T>>,
    free: Vec<u32>,
    len: usize,
}

#[derive(Debug)]
struct Entry<T> {
    /// Bumped on every free; masked to 31 bits so keys stay below `1<<63`.
    gen: u32,
    val: Option<T>,
}

/// Generation mask: 31 bits, keeping bit 63 of the composed key clear.
const GEN_MASK: u32 = 0x7FFF_FFFF;

const fn key_of(gen: u32, slot: u32) -> u64 {
    ((gen as u64) << 32) | slot as u64
}

const fn slot_of(key: u64) -> u32 {
    key as u32
}

const fn gen_of(key: u64) -> u32 {
    (key >> 32) as u32
}

impl<T> Default for Slab<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> Slab<T> {
    pub fn new() -> Self {
        Self {
            slots: Vec::new(),
            free: Vec::new(),
            len: 0,
        }
    }

    pub fn with_capacity(cap: usize) -> Self {
        Self {
            slots: Vec::with_capacity(cap),
            free: Vec::with_capacity(cap),
            len: 0,
        }
    }

    /// Live entries.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Store `val`, returning its key (`generation << 32 | slot`). Never
    /// allocates while a previously freed slot is available.
    pub fn insert(&mut self, val: T) -> u64 {
        self.len += 1;
        match self.free.pop() {
            Some(slot) => {
                let e = &mut self.slots[slot as usize];
                debug_assert!(e.val.is_none(), "free list pointed at a live slot");
                e.val = Some(val);
                key_of(e.gen, slot)
            }
            None => {
                let slot = self.slots.len() as u32;
                assert!(slot != u32::MAX, "slab exhausted 2^32 slots");
                self.slots.push(Entry {
                    gen: 0,
                    val: Some(val),
                });
                key_of(0, slot)
            }
        }
    }

    /// The entry for `key`, unless the key is stale (its slot was freed —
    /// and possibly recycled under a newer generation) or foreign.
    pub fn get(&self, key: u64) -> Option<&T> {
        let e = self.slots.get(slot_of(key) as usize)?;
        if e.gen != gen_of(key) {
            return None;
        }
        e.val.as_ref()
    }

    /// Mutable access with the same stale-key semantics as [`Slab::get`].
    pub fn get_mut(&mut self, key: u64) -> Option<&mut T> {
        let e = self.slots.get_mut(slot_of(key) as usize)?;
        if e.gen != gen_of(key) {
            return None;
        }
        e.val.as_mut()
    }

    /// Free `key`'s slot and return its value; `None` for stale/foreign
    /// keys (the duplicate-completion guard). The slot's generation is
    /// bumped immediately, so the freed key is dead from this point on.
    pub fn remove(&mut self, key: u64) -> Option<T> {
        let slot = slot_of(key);
        let e = self.slots.get_mut(slot as usize)?;
        if e.gen != gen_of(key) {
            return None;
        }
        let val = e.val.take()?;
        e.gen = (e.gen + 1) & GEN_MASK;
        self.free.push(slot);
        self.len -= 1;
        Some(val)
    }

    /// Iterate live entries as `(key, &value)`.
    pub fn iter(&self) -> impl Iterator<Item = (u64, &T)> {
        self.slots
            .iter()
            .enumerate()
            .filter_map(|(slot, e)| e.val.as_ref().map(|v| (key_of(e.gen, slot as u32), v)))
    }

    /// Iterate live values.
    pub fn values(&self) -> impl Iterator<Item = &T> {
        self.slots.iter().filter_map(|e| e.val.as_ref())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_get_remove_roundtrip() {
        let mut s: Slab<u64> = Slab::new();
        let a = s.insert(10);
        let b = s.insert(20);
        assert_ne!(a, b);
        assert_eq!(s.len(), 2);
        assert_eq!(s.get(a), Some(&10));
        assert_eq!(s.get(b), Some(&20));
        *s.get_mut(a).unwrap() = 11;
        assert_eq!(s.remove(a), Some(11));
        assert_eq!(s.get(a), None, "removed key is dead");
        assert_eq!(s.remove(a), None, "double remove is a no-op");
        assert_eq!(s.len(), 1);
        assert_eq!(s.remove(b), Some(20));
        assert!(s.is_empty());
    }

    #[test]
    fn recycled_slot_rejects_stale_key() {
        let mut s: Slab<&'static str> = Slab::new();
        let old = s.insert("old");
        assert_eq!(s.remove(old), Some("old"));
        let new = s.insert("new");
        // same slot, new generation: the stale key must not resolve
        assert_ne!(old, new);
        assert_eq!(old as u32, new as u32, "slot reused");
        assert_eq!(s.get(old), None);
        assert_eq!(s.remove(old), None, "stale key cannot evict the tenant");
        assert_eq!(s.get(new), Some(&"new"));
    }

    #[test]
    fn keys_stay_below_the_reserved_id_space() {
        let mut s: Slab<u8> = Slab::new();
        let k = s.insert(1);
        assert!(k < 1 << 63);
        assert!(k < u64::MAX, "the resync sentinel is unreachable");
    }

    #[test]
    fn steady_state_reuses_capacity() {
        let mut s: Slab<u64> = Slab::new();
        let mut keys = Vec::new();
        for i in 0..64 {
            keys.push(s.insert(i));
        }
        for _ in 0..1000 {
            for k in keys.drain(..) {
                assert!(s.remove(k).is_some());
            }
            for i in 0..64 {
                keys.push(s.insert(i));
            }
        }
        assert_eq!(s.len(), 64);
        assert_eq!(s.slots.len(), 64, "no slot growth at steady state");
    }

    #[test]
    fn iteration_sees_exactly_the_live_entries() {
        let mut s: Slab<u64> = Slab::new();
        let a = s.insert(1);
        let b = s.insert(2);
        let c = s.insert(3);
        s.remove(b);
        let mut live: Vec<(u64, u64)> = s.iter().map(|(k, &v)| (k, v)).collect();
        live.sort_unstable();
        let mut want = vec![(a, 1), (c, 3)];
        want.sort_unstable();
        assert_eq!(live, want);
        assert_eq!(s.values().sum::<u64>(), 4);
    }

    /// Satellite property (ISSUE 5): a stale (old-generation) key from a
    /// late or duplicate completion never resolves to a recycled slot —
    /// against a model tracking every key ever freed, under random
    /// insert/remove interleavings with heavy slot reuse.
    #[test]
    fn prop_stale_keys_never_resolve_after_recycling() {
        use crate::util::fxhash::FxHashMap;
        crate::util::prop::forall(crate::util::prop::cfg(0x51AB), |rng, size| {
            let mut s: Slab<u64> = Slab::new();
            let mut live: FxHashMap<u64, u64> = FxHashMap::default();
            let mut dead: Vec<u64> = Vec::new();
            let mut next_val = 0u64;
            for _ in 0..size * 8 {
                if live.is_empty() || rng.gen_bool(0.5) {
                    let key = s.insert(next_val);
                    if live.insert(key, next_val).is_some() {
                        return Err(format!("key {key:#x} issued twice while live"));
                    }
                    if dead.contains(&key) {
                        return Err(format!("key {key:#x} reissued after death"));
                    }
                    next_val += 1;
                } else {
                    let i = rng.gen_below(live.len() as u64) as usize;
                    let key = *live.keys().nth(i).unwrap();
                    let want = live.remove(&key).unwrap();
                    match s.remove(key) {
                        Some(v) if v == want => dead.push(key),
                        other => return Err(format!("remove({key:#x}) -> {other:?}")),
                    }
                }
                // every dead key must stay dead, whatever now occupies
                // its slot (this is the duplicate-WC guarantee)
                for &k in dead.iter().rev().take(8) {
                    if s.get(k).is_some() {
                        return Err(format!("stale key {k:#x} resolved"));
                    }
                }
                if s.len() != live.len() {
                    return Err(format!("len {} != model {}", s.len(), live.len()));
                }
            }
            // full audit at the end
            for &k in &dead {
                if s.get(k).is_some() || s.remove(k).is_some() {
                    return Err(format!("stale key {k:#x} resolved at audit"));
                }
            }
            for (&k, &v) in &live {
                if s.get(k) != Some(&v) {
                    return Err(format!("live key {k:#x} lost"));
                }
            }
            Ok(())
        });
    }
}
