//! Shared virtual-time event scheduler for the discrete-event backends.
//!
//! Both DES backends (`fabric::sim` and `fabric::chaos`) used to carry
//! their own `BinaryHeap<Reverse<(at, seq, ev)>>` loop. [`EventQueue`] is
//! the one shared replacement: a **calendar queue** (a 1024-bucket timing
//! wheel over 4096 ns slots with a sorted "near" lane and an unsorted
//! "far" overflow tier) that stays O(1) amortized per operation at
//! thousands of nodes, while popping in exactly the order the heaps did —
//! globally minimal `(at, seq)` with FIFO tie-breaking on the internal
//! sequence number, so pinned chaos seeds replay bit-identically.
//!
//! [`ReferenceQueue`] preserves the pre-refactor `BinaryHeap` scheduler
//! verbatim. It exists so equivalence is a *test*, not a hope: the chaos
//! fabric can be built against either scheduler
//! (`ChaosFabric::build_with_scheduler`) and `tests/pinned_replay.rs`
//! asserts the full scenario reports match field-for-field.
//!
//! # Structure
//!
//! Virtual time is split into three tiers by distance from `now`:
//!
//! * **near** — a single `Vec` sorted *descending* by `(at, seq)` so the
//!   minimum pops from the end in O(1). Covers `[now, near_end)`.
//! * **wheel** — `NBUCKETS` unsorted buckets of `BUCKET_NS` each,
//!   covering `[near_end, near_end + NBUCKETS * BUCKET_NS)`. A push is
//!   O(1) (index by `at / BUCKET_NS mod NBUCKETS`); when the near lane
//!   drains, the first non-empty bucket is swapped in wholesale (the two
//!   `Vec`s trade capacity, so steady state allocates nothing) and
//!   sorted once — O(k log k) for k events that each cost O(log n) in a
//!   heap.
//! * **far** — an unsorted overflow `Vec` for events beyond the wheel
//!   horizon. Before every bucket scan the queue flushes far events that
//!   the advancing horizon has caught up with; when the wheel is empty
//!   it rebases the window onto the earliest far event.
//!
//! The bucket width matches the fabrics' event scale (deliveries land
//! 1–9 µs out, so a handful share a bucket) and the wheel spans ~4.2 ms
//! of virtual time, which covers every in-flight completion; only
//! long-range control events (node revivals, storm ends) ever touch the
//! far tier.

use std::cmp::{Ordering, Reverse};
use std::collections::BinaryHeap;

/// Number of buckets in the wheel (one lap spans `NBUCKETS * BUCKET_NS`).
const NBUCKETS: usize = 1024;
/// Width of one bucket in virtual nanoseconds.
const BUCKET_NS: u64 = 4096;
/// One full lap of the wheel in virtual nanoseconds.
const WINDOW_NS: u64 = NBUCKETS as u64 * BUCKET_NS;

/// A scheduled event: fire time, insertion sequence, payload.
struct Entry<T> {
    at: u64,
    seq: u64,
    item: T,
}

/// Calendar-queue scheduler. Pops `(at, item)` pairs in ascending
/// `(at, seq)` order, where `seq` is the queue-internal insertion
/// counter — i.e. FIFO among events scheduled for the same instant.
pub struct EventQueue<T> {
    /// Sorted descending by `(at, seq)`; the minimum is at the end.
    near: Vec<Entry<T>>,
    /// Exclusive upper bound of the near lane (a `BUCKET_NS` multiple).
    near_end: u64,
    /// The wheel: unsorted buckets indexed by `(at / BUCKET_NS) % NBUCKETS`.
    buckets: Vec<Vec<Entry<T>>>,
    /// Total entries currently in the wheel.
    wheel_len: usize,
    /// Unsorted overflow beyond the wheel horizon.
    far: Vec<Entry<T>>,
    /// Earliest `at` in the far tier (`u64::MAX` when it is empty):
    /// flushing can be skipped entirely until the advancing horizon
    /// reaches this watermark, so a large far population (the `Scale`
    /// profile's long-range control events) costs nothing per bucket
    /// swap instead of an O(|far|) rescan.
    far_min: u64,
    /// Virtual time of the last popped event; pushes clamp to it.
    now: u64,
    /// Insertion counter (tie-break within an instant).
    next_seq: u64,
    /// Total entries across all tiers.
    len: usize,
}

impl<T> Default for EventQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> EventQueue<T> {
    /// An empty queue at virtual time 0.
    pub fn new() -> Self {
        EventQueue {
            near: Vec::new(),
            near_end: 0,
            buckets: std::iter::repeat_with(Vec::new).take(NBUCKETS).collect(),
            wheel_len: 0,
            far: Vec::new(),
            far_min: u64::MAX,
            now: 0,
            next_seq: 0,
            len: 0,
        }
    }

    /// Number of pending events.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether no events are pending.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Virtual time of the most recently popped event.
    pub fn now(&self) -> u64 {
        self.now
    }

    /// Schedule `item` at virtual time `at` (clamped to never precede
    /// the last popped event, exactly as the old schedulers clamped).
    pub fn push(&mut self, at: u64, item: T) {
        let at = at.max(self.now);
        let seq = self.next_seq;
        self.next_seq += 1;
        self.len += 1;
        let entry = Entry { at, seq, item };
        if at < self.near_end {
            // rare: an event lands inside the already-sorted lane
            let idx = self.near.partition_point(|e| (e.at, e.seq) > (at, seq));
            self.near.insert(idx, entry);
        } else if at < self.near_end + WINDOW_NS {
            let idx = ((at / BUCKET_NS) % NBUCKETS as u64) as usize;
            self.buckets[idx].push(entry);
            self.wheel_len += 1;
        } else {
            self.far_min = self.far_min.min(at);
            self.far.push(entry);
        }
    }

    /// Pop the earliest event as `(at, item)`; ties pop in push order.
    pub fn pop(&mut self) -> Option<(u64, T)> {
        if !self.service_to_near() {
            return None;
        }
        let e = self.near.pop().expect("service_to_near filled the lane");
        self.len -= 1;
        self.now = e.at;
        Some((e.at, e.item))
    }

    /// Fire time of the earliest pending event, without popping it (and
    /// without advancing `now`). `&mut` because locating the minimum may
    /// swap a wheel bucket into the near lane — a reshuffle of internal
    /// tiers that never changes the pop order. Lets the engine's deadline
    /// timer ask "when is the next expiry?" cheaply between drains.
    pub fn peek_at(&mut self) -> Option<u64> {
        if !self.service_to_near() {
            return None;
        }
        self.near.last().map(|e| e.at)
    }

    /// Advance tiers until the near lane holds the global minimum at its
    /// end; `false` iff the queue is empty.
    fn service_to_near(&mut self) -> bool {
        loop {
            if !self.near.is_empty() {
                return true;
            }
            if self.wheel_len == 0 {
                if self.far.is_empty() {
                    return false;
                }
                self.rebase_onto_far();
                continue;
            }
            // The horizon may have advanced past far events scheduled
            // under an older window; pull them into the wheel *before*
            // scanning, or a later bucket could pop ahead of them.
            self.flush_far_into_wheel();
            let first = self.near_end / BUCKET_NS;
            let mut serviced = false;
            for off in 0..NBUCKETS as u64 {
                let slot = first + off;
                let idx = (slot % NBUCKETS as u64) as usize;
                if self.buckets[idx].is_empty() {
                    continue;
                }
                // Swap the bucket in wholesale: the drained near lane's
                // capacity moves into the bucket for reuse, so steady
                // state allocates nothing.
                std::mem::swap(&mut self.near, &mut self.buckets[idx]);
                self.wheel_len -= self.near.len();
                self.near
                    .sort_unstable_by(|a, b| (b.at, b.seq).cmp(&(a.at, a.seq)));
                self.near_end = (slot + 1) * BUCKET_NS;
                serviced = true;
                break;
            }
            debug_assert!(serviced, "wheel_len > 0 but every bucket was empty");
            if !serviced {
                // unreachable by construction; avoid an infinite loop
                // in release builds if the invariant is ever broken
                self.wheel_len = 0;
            }
        }
    }

    /// Move far events the advancing horizon has caught up with into
    /// their wheel buckets. Every moved event has `at >= near_end`
    /// (far events are at or beyond the horizon that existed when they
    /// were pushed, and `near_end` never advances past that horizon).
    fn flush_far_into_wheel(&mut self) {
        let horizon = self.near_end + WINDOW_NS;
        // Watermark early-out: `far_min` is the exact minimum `at` in the
        // far tier, so if the horizon has not reached it, no far event
        // qualifies — skip the scan entirely. This is the common case:
        // the horizon advances one bucket at a time while far events sit
        // milliseconds out.
        if self.far_min >= horizon {
            debug_assert!(self.far.iter().all(|e| e.at >= horizon));
            return;
        }
        let mut remaining_min = u64::MAX;
        let mut i = 0;
        while i < self.far.len() {
            if self.far[i].at < horizon {
                let e = self.far.swap_remove(i);
                debug_assert!(e.at >= self.near_end);
                let idx = ((e.at / BUCKET_NS) % NBUCKETS as u64) as usize;
                self.buckets[idx].push(e);
                self.wheel_len += 1;
            } else {
                remaining_min = remaining_min.min(self.far[i].at);
                i += 1;
            }
        }
        self.far_min = remaining_min;
    }

    /// Wheel and near lane are empty but far is not: fast-forward the
    /// window so it starts at the earliest far event's bucket, then
    /// flush. Guaranteed to move at least that event into the wheel.
    fn rebase_onto_far(&mut self) {
        debug_assert!(self.near.is_empty() && self.wheel_len == 0);
        // the watermark *is* the minimum (maintained on push, recomputed
        // on every flush), so rebasing no longer scans the far tier
        let min_at = self.far_min;
        debug_assert_eq!(
            Some(min_at),
            self.far.iter().map(|e| e.at).min(),
            "far watermark out of sync with the far tier"
        );
        self.near_end = self.near_end.max((min_at / BUCKET_NS) * BUCKET_NS);
        self.flush_far_into_wheel();
        debug_assert!(self.wheel_len > 0);
    }
}

/// The pre-refactor scheduler, verbatim: a `BinaryHeap` of
/// `Reverse<(at, seq, item)>` ordered by `(at, seq)` only. Kept so the
/// calendar queue's pop order can be asserted against the original
/// implementation run-for-run (see `tests/pinned_replay.rs`), and as
/// the model for the property tests below.
pub struct ReferenceQueue<T> {
    heap: BinaryHeap<Reverse<RefEntry<T>>>,
    now: u64,
    next_seq: u64,
}

struct RefEntry<T> {
    at: u64,
    seq: u64,
    item: T,
}

// Order by (at, seq) only — the payload never participates, exactly as
// the old `Event`/`HeapEv` manual impls had it.
impl<T> PartialEq for RefEntry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<T> Eq for RefEntry<T> {}
impl<T> PartialOrd for RefEntry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<T> Ord for RefEntry<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        (self.at, self.seq).cmp(&(other.at, other.seq))
    }
}

impl<T> Default for ReferenceQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> ReferenceQueue<T> {
    /// An empty queue at virtual time 0.
    pub fn new() -> Self {
        ReferenceQueue {
            heap: BinaryHeap::new(),
            now: 0,
            next_seq: 0,
        }
    }

    /// Number of pending events.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Whether no events are pending.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Virtual time of the most recently popped event.
    pub fn now(&self) -> u64 {
        self.now
    }

    /// Schedule `item` at virtual time `at` (same clamp as
    /// [`EventQueue::push`]).
    pub fn push(&mut self, at: u64, item: T) {
        let at = at.max(self.now);
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Reverse(RefEntry { at, seq, item }));
    }

    /// Pop the earliest event as `(at, item)`; ties pop in push order.
    pub fn pop(&mut self) -> Option<(u64, T)> {
        let Reverse(e) = self.heap.pop()?;
        self.now = e.at;
        Some((e.at, e.item))
    }

    /// Fire time of the earliest pending event, without popping it
    /// (API parity with [`EventQueue::peek_at`]).
    pub fn peek_at(&mut self) -> Option<u64> {
        self.heap.peek().map(|Reverse(e)| e.at)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    /// Drive the calendar queue, the reference queue, and a plain
    /// `BinaryHeap` model through one interleaved push/pop schedule and
    /// demand identical pop sequences (times *and* payloads, so FIFO
    /// tie-breaks are checked, not just timestamps).
    fn drive(seed: u64, ops: usize, max_gap: u64, tie_bias: bool) {
        let mut rng = Pcg32::new(seed);
        let mut cal: EventQueue<u64> = EventQueue::new();
        let mut reference: ReferenceQueue<u64> = ReferenceQueue::new();
        let mut model: BinaryHeap<Reverse<(u64, u64, u64)>> = BinaryHeap::new();
        let mut model_seq = 0u64;
        let mut model_now = 0u64;
        let mut payload = 0u64;
        for _ in 0..ops {
            let do_push = cal.is_empty() || rng.gen_bool(0.6);
            if do_push {
                let mut at = cal.now() + rng.gen_below(max_gap.max(1));
                if tie_bias && rng.gen_bool(0.5) {
                    // heavy tie pressure: reuse the current instant
                    at = cal.now();
                }
                payload += 1;
                cal.push(at, payload);
                reference.push(at, payload);
                let clamped = at.max(model_now);
                model.push(Reverse((clamped, model_seq, payload)));
                model_seq += 1;
            } else {
                let got = cal.pop();
                let refr = reference.pop();
                let want = model.pop().map(|Reverse((t, _, p))| (t, p));
                if let Some((t, _)) = want {
                    model_now = t;
                }
                assert_eq!(got, want, "calendar diverged from the model");
                assert_eq!(refr, want, "reference diverged from the model");
            }
        }
        // drain: every remaining event in identical order
        loop {
            let got = cal.pop();
            let refr = reference.pop();
            let want = model.pop().map(|Reverse((t, _, p))| (t, p));
            assert_eq!(got, want);
            assert_eq!(refr, want);
            if want.is_none() {
                break;
            }
        }
        assert!(cal.is_empty() && reference.is_empty());
    }

    #[test]
    fn matches_heap_model_at_fabric_timescales() {
        // gaps shaped like the chaos fabric's deliveries (1–9 µs)
        for seed in 0..8 {
            drive(seed, 4_000, 9_000, false);
        }
    }

    #[test]
    fn matches_heap_model_under_fifo_tie_pressure() {
        for seed in 0..8 {
            drive(0x71E ^ seed, 2_000, 64, true);
        }
    }

    #[test]
    fn matches_heap_model_across_the_far_horizon() {
        // gaps far beyond one wheel lap (4.19 ms) force the far tier
        // and the rebase/flush paths
        for seed in 0..8 {
            drive(0xFA2 ^ seed, 2_000, 40 * WINDOW_NS, false);
        }
    }

    #[test]
    fn fifo_ties_pop_in_push_order() {
        let mut q: EventQueue<u64> = EventQueue::new();
        for i in 0..500 {
            q.push(12_345, i);
        }
        for i in 0..500 {
            assert_eq!(q.pop(), Some((12_345, i)));
        }
        assert!(q.is_empty());
    }

    #[test]
    fn past_pushes_clamp_to_now() {
        let mut q: EventQueue<u64> = EventQueue::new();
        q.push(10_000, 1);
        assert_eq!(q.pop(), Some((10_000, 1)));
        q.push(5, 2); // in the past: clamps to now
        q.push(10_000, 3); // same instant as now, later seq
        assert_eq!(q.pop(), Some((10_000, 2)));
        assert_eq!(q.pop(), Some((10_000, 3)));
        assert_eq!(q.pop(), None);
        assert_eq!(q.now(), 10_000);
    }

    #[test]
    fn far_events_caught_by_the_advancing_horizon_keep_order() {
        // One event just beyond the initial horizon, then a stream of
        // near events that advances the window past it: the far event
        // must pop in global order, not after the whole wheel drains.
        let mut q: EventQueue<u64> = EventQueue::new();
        q.push(WINDOW_NS + 10, 999); // far at push time
        let mut payload = 0;
        let mut at = 0;
        while at < 2 * WINDOW_NS {
            q.push(at, payload);
            payload += 1;
            at += 1_000;
        }
        let mut last = (0u64, 0u64);
        let mut seen_far = false;
        let mut prev_at = 0u64;
        while let Some((t, p)) = q.pop() {
            assert!(t >= prev_at, "time ran backwards: {t} after {prev_at}");
            prev_at = t;
            if p == 999 {
                seen_far = true;
                assert_eq!(t, WINDOW_NS + 10);
            } else if !seen_far {
                last = (t, p);
            }
        }
        assert!(seen_far);
        // the event popped right before the far one is the last near
        // event scheduled before WINDOW_NS + 10
        assert!(last.0 <= WINDOW_NS + 10);
    }

    /// The far watermark must track the true minimum through pushes that
    /// lower it, partial flushes that raise it, and rebases that consume
    /// it — any drift either pops out of order (flushed too late) or
    /// trips the `rebase_onto_far` exactness assert.
    #[test]
    fn far_watermark_survives_mixed_pushes_and_partial_flushes() {
        let mut q: EventQueue<u64> = EventQueue::new();
        q.push(30 * WINDOW_NS, 1); // far
        q.push(5 * WINDOW_NS, 2); // far, lowers the watermark
        q.push(100, 3); // wheel
        assert_eq!(q.pop(), Some((100, 3)));
        // rebase consumes the 5-lap event, leaving the 30-lap one far
        assert_eq!(q.pop(), Some((5 * WINDOW_NS, 2)));
        q.push(6 * WINDOW_NS, 4); // far again, below the survivor
        assert_eq!(q.pop(), Some((6 * WINDOW_NS, 4)));
        assert_eq!(q.pop(), Some((30 * WINDOW_NS, 1)));
        assert_eq!(q.pop(), None);
    }

    /// `peek_at` must agree with the next `pop` across every tier
    /// transition (near, wheel swap, far rebase) and must not perturb
    /// the pop order it previews.
    #[test]
    fn peek_matches_next_pop_across_tiers() {
        let mut rng = Pcg32::new(0x9EEB);
        let mut q: EventQueue<u64> = EventQueue::new();
        let mut reference: ReferenceQueue<u64> = ReferenceQueue::new();
        for i in 0..3_000u64 {
            // mix of near, wheel and far gaps, with ties
            let gap = match rng.gen_below(4) {
                0 => 0,
                1 => rng.gen_below(BUCKET_NS),
                2 => rng.gen_below(WINDOW_NS),
                _ => WINDOW_NS + rng.gen_below(8 * WINDOW_NS),
            };
            q.push(q.now() + gap, i);
            reference.push(reference.now() + gap, i);
            if rng.gen_bool(0.5) {
                assert_eq!(q.peek_at(), reference.peek_at());
                let want_at = q.peek_at();
                let got = q.pop();
                assert_eq!(got.map(|(t, _)| t), want_at);
                assert_eq!(got, reference.pop());
            }
        }
        while let Some(at) = q.peek_at() {
            assert_eq!(q.pop().map(|(t, _)| t), Some(at));
            reference.pop();
        }
        assert_eq!(q.peek_at(), None);
        assert_eq!(reference.peek_at(), None);
        assert!(reference.is_empty());
    }

    #[test]
    fn len_tracks_all_tiers() {
        let mut q: EventQueue<u64> = EventQueue::new();
        q.push(100, 1); // wheel
        q.push(10 * WINDOW_NS, 2); // far
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop(), Some((100, 1)));
        assert_eq!(q.len(), 1);
        q.push(150, 3); // below near_end now: sorted-lane insert
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop(), Some((150, 3)));
        assert_eq!(q.pop(), Some((10 * WINDOW_NS, 2)));
        assert!(q.is_empty());
    }
}
