//! Deterministic pseudo-random number generators.
//!
//! The offline registry has no `rand` crate, so we ship our own small,
//! well-known generators: SplitMix64 (seeding / cheap streams) and PCG32
//! (main workload generator). Both are deterministic across platforms,
//! which the experiment harness relies on for reproducible figures.

/// SplitMix64: tiny, fast, passes BigCrush when used as a stream. Used for
/// seeding and for cheap decorrelated sub-streams.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// PCG32 (XSH-RR 64/32): the default generator for workloads and property
/// tests. Small state, excellent statistical quality, trivially seedable.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    pub const DEFAULT_STREAM: u64 = 0xda3e_39cb_94b9_5bdb;

    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, Self::DEFAULT_STREAM)
    }

    /// Independent stream per `stream` value (odd increment derived from it).
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Self {
            state: 0,
            inc: (stream << 1) | 1,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, bound)` without modulo bias (Lemire's method).
    #[inline]
    pub fn gen_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // 128-bit multiply rejection sampling.
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= (u64::MAX - bound + 1) % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn gen_range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo < hi);
        lo + self.gen_below(hi - lo)
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli with probability `p`.
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        let n = xs.len();
        for i in (1..n).rev() {
            let j = self.gen_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Exponentially distributed sample with the given mean (inter-arrival
    /// times for open-loop workloads).
    #[inline]
    pub fn gen_exp(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.gen_f64(); // (0, 1]
        -mean * u.ln()
    }

    /// Standard normal via Box–Muller (used by synthetic ML datasets).
    pub fn gen_normal(&mut self) -> f64 {
        let u1 = 1.0 - self.gen_f64();
        let u2 = self.gen_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_is_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn splitmix_differs_across_seeds() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn pcg_deterministic_and_stream_separated() {
        let mut a = Pcg32::new(7);
        let mut b = Pcg32::new(7);
        let mut c = Pcg32::with_stream(7, 99);
        let va: Vec<u32> = (0..8).map(|_| a.next_u32()).collect();
        let vb: Vec<u32> = (0..8).map(|_| b.next_u32()).collect();
        let vc: Vec<u32> = (0..8).map(|_| c.next_u32()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn gen_below_respects_bound() {
        let mut rng = Pcg32::new(3);
        for bound in [1u64, 2, 3, 10, 1000, u32::MAX as u64] {
            for _ in 0..200 {
                assert!(rng.gen_below(bound) < bound);
            }
        }
    }

    #[test]
    fn gen_range_covers_small_range() {
        let mut rng = Pcg32::new(5);
        let mut seen = [false; 4];
        for _ in 0..200 {
            seen[rng.gen_range(0, 4) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gen_f64_in_unit_interval_and_roughly_uniform() {
        let mut rng = Pcg32::new(11);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = rng.gen_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg32::new(17);
        let mut xs: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn gen_exp_mean_close() {
        let mut rng = Pcg32::new(23);
        let n = 50_000;
        let mean = 4.0;
        let sum: f64 = (0..n).map(|_| rng.gen_exp(mean)).sum();
        let m = sum / n as f64;
        assert!((m - mean).abs() / mean < 0.05, "measured {m}");
    }

    #[test]
    fn gen_normal_moments() {
        let mut rng = Pcg32::new(29);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.gen_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }
}
