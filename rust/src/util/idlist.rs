//! [`IdList`] — a small-vector of `u64` ids that stays inline (no heap
//! allocation) up to [`INLINE_IDS`] entries and spills to a `Vec` beyond.
//!
//! This is what lets a [`crate::fabric::WorkRequest`] carry its app-I/O
//! ids through the merge → plan → post → retire cycle without a per-WR
//! heap allocation: the default NIC merge width (`BatchLimits::max_sge` =
//! 16) fits inline, so the steady-state pipeline moves ids by memcpy.
//! Configurations with a wider SGE limit still work — they pay one spill
//! allocation per oversized WR, which the allocation-gated bench would
//! surface if it ever crept onto the default path.
//!
//! The storage is contiguous in either representation, so the list derefs
//! to `&[u64]` and call sites use it exactly like the `Vec<u64>` it
//! replaced (iteration, indexing, `contains`, comparisons).

/// Ids stored inline before spilling to the heap. Matches the default
/// `max_sge` merge width so default-config WRs never allocate.
pub const INLINE_IDS: usize = 16;

/// A `u64` list, inline up to [`INLINE_IDS`] entries.
#[derive(Debug, Clone)]
pub enum IdList {
    /// The common case: ids in a fixed array, `len` of them valid.
    Inline { buf: [u64; INLINE_IDS], len: u8 },
    /// Spilled: more ids than the inline buffer holds.
    Heap(Vec<u64>),
}

impl Default for IdList {
    fn default() -> Self {
        Self::new()
    }
}

impl IdList {
    pub const fn new() -> Self {
        Self::Inline {
            buf: [0; INLINE_IDS],
            len: 0,
        }
    }

    pub fn push(&mut self, id: u64) {
        match self {
            Self::Inline { buf, len } => {
                if (*len as usize) < INLINE_IDS {
                    buf[*len as usize] = id;
                    *len += 1;
                } else {
                    let mut v = Vec::with_capacity(INLINE_IDS * 2);
                    v.extend_from_slice(&buf[..]);
                    v.push(id);
                    *self = Self::Heap(v);
                }
            }
            Self::Heap(v) => v.push(id),
        }
    }

    pub fn as_slice(&self) -> &[u64] {
        match self {
            Self::Inline { buf, len } => &buf[..*len as usize],
            Self::Heap(v) => v,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Self::Inline { len, .. } => *len as usize,
            Self::Heap(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn clear(&mut self) {
        match self {
            Self::Inline { len, .. } => *len = 0,
            Self::Heap(v) => v.clear(),
        }
    }
}

impl std::ops::Deref for IdList {
    type Target = [u64];
    fn deref(&self) -> &[u64] {
        self.as_slice()
    }
}

impl FromIterator<u64> for IdList {
    fn from_iter<I: IntoIterator<Item = u64>>(iter: I) -> Self {
        let mut out = Self::new();
        for id in iter {
            out.push(id);
        }
        out
    }
}

impl From<Vec<u64>> for IdList {
    fn from(v: Vec<u64>) -> Self {
        if v.len() <= INLINE_IDS {
            v.into_iter().collect()
        } else {
            Self::Heap(v)
        }
    }
}

impl<'a> IntoIterator for &'a IdList {
    type Item = &'a u64;
    type IntoIter = std::slice::Iter<'a, u64>;
    fn into_iter(self) -> Self::IntoIter {
        self.as_slice().iter()
    }
}

impl PartialEq for IdList {
    fn eq(&self, other: &Self) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl Eq for IdList {}

impl PartialEq<Vec<u64>> for IdList {
    fn eq(&self, other: &Vec<u64>) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl PartialEq<&[u64]> for IdList {
    fn eq(&self, other: &&[u64]) -> bool {
        self.as_slice() == *other
    }
}

impl<const N: usize> PartialEq<[u64; N]> for IdList {
    fn eq(&self, other: &[u64; N]) -> bool {
        self.as_slice() == &other[..]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inline_until_the_cap_then_spills() {
        let mut l = IdList::new();
        for i in 0..INLINE_IDS as u64 {
            l.push(i);
        }
        assert!(matches!(l, IdList::Inline { .. }));
        assert_eq!(l.len(), INLINE_IDS);
        l.push(99);
        assert!(matches!(l, IdList::Heap(_)), "17th id spills");
        assert_eq!(l.len(), INLINE_IDS + 1);
        assert_eq!(l[INLINE_IDS], 99);
        // order preserved across the spill
        let want: Vec<u64> = (0..INLINE_IDS as u64).chain([99]).collect();
        assert_eq!(l, want);
    }

    #[test]
    fn behaves_like_a_slice() {
        let l: IdList = [5u64, 6, 7].into_iter().collect();
        assert_eq!(l.len(), 3);
        assert_eq!(l[0], 5);
        assert!(l.contains(&6));
        assert_eq!(l.iter().sum::<u64>(), 18);
        assert_eq!(l, vec![5, 6, 7]);
        let mut seen = Vec::new();
        for &id in &l {
            seen.push(id);
        }
        assert_eq!(seen, vec![5, 6, 7]);
        let cloned = l.clone();
        assert_eq!(cloned, l);
    }

    #[test]
    fn from_vec_and_clear() {
        let l: IdList = vec![1u64; INLINE_IDS + 4].into();
        assert!(matches!(l, IdList::Heap(_)));
        let mut s: IdList = vec![1u64, 2].into();
        assert!(matches!(s, IdList::Inline { .. }));
        s.clear();
        assert!(s.is_empty());
        assert_eq!(s, Vec::<u64>::new());
    }
}
