//! Log-bucketed latency histogram (HDR-histogram style, no deps).
//!
//! Values are nanoseconds (u64). Buckets: 64 major buckets (one per leading
//! bit) × `SUB` minor buckets each, giving ~1.6% relative error — plenty for
//! p99/p99.9 tail-latency figures (Fig 7, Fig 12).

const SUB_BITS: u32 = 6;
const SUB: usize = 1 << SUB_BITS; // 64 sub-buckets per power of two

#[derive(Clone)]
pub struct Hist {
    counts: Vec<u64>,
    total: u64,
    sum: u128,
    min: u64,
    max: u64,
}

impl Default for Hist {
    fn default() -> Self {
        Self::new()
    }
}

impl Hist {
    pub fn new() -> Self {
        Self {
            counts: vec![0; 64 * SUB],
            total: 0,
            sum: 0,
            min: u64::MAX,
            max: 0,
        }
    }

    #[inline]
    fn index(v: u64) -> usize {
        if v < SUB as u64 {
            return v as usize;
        }
        let msb = 63 - v.leading_zeros(); // >= SUB_BITS
        let major = (msb - SUB_BITS + 1) as usize;
        let shift = msb - SUB_BITS;
        let minor = ((v >> shift) & (SUB as u64 - 1)) as usize;
        major * SUB + minor
    }

    /// Representative (upper-edge midpoint) value of bucket `i`.
    fn value_of(i: usize) -> u64 {
        let major = i / SUB;
        let minor = (i % SUB) as u64;
        if major == 0 {
            return minor;
        }
        let shift = major as u32 - 1;
        ((SUB as u64 + minor) << shift) + (1u64 << shift) / 2
    }

    #[inline]
    pub fn record(&mut self, v: u64) {
        self.counts[Self::index(v)] += 1;
        self.total += 1;
        self.sum += v as u128;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    #[inline]
    pub fn record_n(&mut self, v: u64, n: u64) {
        if n == 0 {
            return;
        }
        self.counts[Self::index(v)] += n;
        self.total += n;
        self.sum += v as u128 * n as u128;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn merge(&mut self, other: &Hist) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.total += other.total;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        self.sum as f64 / self.total as f64
    }

    pub fn min(&self) -> u64 {
        if self.total == 0 {
            0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    /// Quantile in `[0, 1]`; returns a representative value.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let q = q.clamp(0.0, 1.0);
        let target = ((q * self.total as f64).ceil() as u64).max(1);
        let mut acc = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return Self::value_of(i).min(self.max).max(self.min);
            }
        }
        self.max
    }

    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }
    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }
    pub fn p999(&self) -> u64 {
        self.quantile(0.999)
    }

    pub fn clear(&mut self) {
        self.counts.iter_mut().for_each(|c| *c = 0);
        self.total = 0;
        self.sum = 0;
        self.min = u64::MAX;
        self.max = 0;
    }
}

impl std::fmt::Debug for Hist {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "Hist{{n={} mean={:.0} p50={} p99={} max={}}}",
            self.total,
            self.mean(),
            self.p50(),
            self.p99(),
            self.max()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn empty_hist_is_zero() {
        let h = Hist::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.p99(), 0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn small_values_exact() {
        let mut h = Hist::new();
        for v in 0..SUB as u64 {
            h.record(v);
        }
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), SUB as u64 - 1);
        assert_eq!(h.quantile(0.0), 0);
    }

    #[test]
    fn quantile_relative_error_bounded() {
        let mut h = Hist::new();
        let mut rng = Pcg32::new(1);
        let mut vals: Vec<u64> = (0..100_000).map(|_| rng.gen_range(100, 10_000_000)).collect();
        for &v in &vals {
            h.record(v);
        }
        vals.sort_unstable();
        for q in [0.5, 0.9, 0.99, 0.999] {
            let exact = vals[((q * vals.len() as f64) as usize).min(vals.len() - 1)];
            let approx = h.quantile(q);
            let rel = (approx as f64 - exact as f64).abs() / exact as f64;
            assert!(rel < 0.05, "q={q} exact={exact} approx={approx} rel={rel}");
        }
    }

    #[test]
    fn mean_is_exact() {
        let mut h = Hist::new();
        for v in [10u64, 20, 30, 40] {
            h.record(v);
        }
        assert!((h.mean() - 25.0).abs() < 1e-9);
    }

    #[test]
    fn merge_combines() {
        let mut a = Hist::new();
        let mut b = Hist::new();
        a.record(100);
        b.record(1_000_000);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.min(), 100);
        assert!(a.max() >= 1_000_000);
    }

    #[test]
    fn record_n_equivalent_to_loop() {
        let mut a = Hist::new();
        let mut b = Hist::new();
        a.record_n(12345, 10);
        for _ in 0..10 {
            b.record(12345);
        }
        assert_eq!(a.count(), b.count());
        assert_eq!(a.p50(), b.p50());
        assert!((a.mean() - b.mean()).abs() < 1e-9);
    }

    #[test]
    fn quantiles_monotone() {
        let mut h = Hist::new();
        let mut rng = Pcg32::new(9);
        for _ in 0..10_000 {
            h.record(rng.gen_range(1, 1_000_000));
        }
        let mut last = 0;
        for i in 0..=100 {
            let v = h.quantile(i as f64 / 100.0);
            assert!(v >= last);
            last = v;
        }
    }
}
