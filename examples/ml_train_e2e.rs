//! END-TO-END driver: all three layers composing on a real workload.
//!
//! * L3 — the live RDMAbox coordinator (`IoEngine`: sharded merge queues,
//!   batch planner, admission window) moves real bytes between loopback
//!   remote-memory nodes (real threads) and a bounded local page cache.
//! * L2/L1 — each training step executes the AOT-compiled JAX model with
//!   its Pallas kernel (`artifacts/logreg_step.hlo.txt`) on the PJRT CPU
//!   client. Python is nowhere in this process.
//!
//! Trains logistic regression on a synthetic dataset whose pages live on
//! remote nodes (only 25% resident locally), logs the loss curve, and
//! reports paging + coordinator statistics. Recorded in EXPERIMENTS.md.
//!
//! Requires the `xla` cargo feature (PJRT bindings — see README):
//!
//! ```bash
//! make artifacts && cargo run --release --features xla --example ml_train_e2e -- --steps 300
//! ```

#[cfg(feature = "xla")]
fn main() {
    use rdmabox::cli::Args;
    use rdmabox::ml::train_paged_logreg;
    use rdmabox::runtime::Runtime;
    use rdmabox::util::fmt;

    let args = Args::parse_env().unwrap_or_default();
    let steps = args.get_u64("steps", 300).unwrap_or(300) as usize;
    let rows = args.get_u64("rows", 2048).unwrap_or(2048) as usize;
    let resident = args.get_f64("resident", 0.25).unwrap_or(0.25);

    if !rdmabox::runtime::artifacts_available() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }
    let mut rt = Runtime::from_artifacts().expect("PJRT client");
    println!(
        "PJRT platform: {} | logreg (256x512 minibatch) | {} rows on 3 remote nodes, {:.0}% resident",
        rt.platform(),
        rows,
        resident * 100.0
    );

    let t0 = std::time::Instant::now();
    let r = train_paged_logreg(&mut rt, 3, rows, 256, 512, resident, steps, 0.5)
        .expect("training run");
    println!("loss curve:");
    for (i, l) in r.losses.iter().enumerate() {
        if i % 25 == 0 || i + 1 == r.losses.len() {
            println!("  step {i:4}  loss {l:.4}");
        }
    }
    let first = r.losses.first().copied().unwrap_or(0.0);
    let last = r.losses.last().copied().unwrap_or(0.0);
    println!(
        "\ntrained {} steps in {:.1}s (incl. dataset population): loss {first:.4} -> {last:.4}",
        r.steps,
        t0.elapsed().as_secs_f64()
    );
    println!(
        "paging: {} faults, {} hits ({:.1}% hit rate) | {} read from remote | {} app I/Os merged by load-aware batching",
        r.faults,
        r.hits,
        r.hits as f64 / (r.hits + r.faults).max(1) as f64 * 100.0,
        fmt::bytes(r.bytes_read),
        r.merged_ios
    );
    assert!(last < first, "training must reduce the loss");
    println!("ml_train_e2e OK — rust coordinator + PJRT-executed JAX/Pallas compose");
}

#[cfg(not(feature = "xla"))]
fn main() {
    eprintln!(
        "ml_train_e2e needs the PJRT runtime: rebuild with `cargo run --release --features xla \
         --example ml_train_e2e` (see README §PJRT runtime)"
    );
}
