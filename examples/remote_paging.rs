//! Remote paging demo, two halves:
//!
//! 1. **Live loopback**: a paging-style page-out burst driven through the
//!    `IoEngine` pipeline on real threads, comparing 1 vs 4 sharded merge
//!    queues (QPs) per remote node — the §6.1 multi-channel win, live.
//! 2. **Simulated fabric**: VoltDB-style workload under a container memory
//!    limit, RDMAbox vs nbdX (128K / 512K block I/O) — a compact Fig 12.
//!
//! ```bash
//! cargo run --release --example remote_paging [-- --resident 0.25]
//! ```

use std::time::Instant;

use rdmabox::baselines;
use rdmabox::cli::{Args, Table};
use rdmabox::config::FabricConfig;
use rdmabox::coordinator::{EngineSpec, StackConfig};
use rdmabox::fabric::loopback::{LiveBox, LoopbackFabric};
use rdmabox::util::fmt;
use rdmabox::workloads::kv::{run_kv, voltdb, KvConfig, Mix};

/// Page-out burst: `threads` writers each flush `pages` 4 KB pages to the
/// 3-node cluster through the shared pipeline. Returns MB/s of payload
/// plus the pipeline statistics of the run.
fn live_pageout_burst(
    qps_per_node: usize,
    threads: u64,
    pages: u64,
) -> (f64, rdmabox::fabric::loopback::LiveStats) {
    let fabric = LoopbackFabric::start_sharded(3, 64 << 20, qps_per_node);
    let rbox = LiveBox::build(
        fabric,
        &EngineSpec::new(3).qps(qps_per_node).window(Some(7 << 20)),
    );
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for t in 0..threads {
        let b = rbox.clone();
        handles.push(std::thread::spawn(move || {
            let data = vec![0xA5u8; 4096];
            for i in 0..pages {
                // interleaved pages spread over nodes and 1 MiB regions:
                // adjacency for the merger, independent regions for the
                // shards
                let page = i * threads + t;
                let node = (page % 3) as usize;
                let addr = (page % 24) * (1 << 20) + (page / 24) * 4096;
                b.write(node, addr, &data);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let dt = t0.elapsed().as_secs_f64();
    let bytes = threads * pages * 4096;
    (bytes as f64 / dt / 1e6, rbox.stats())
}

fn main() {
    let args = Args::parse_env().unwrap_or_default();
    let resident = args.get_f64("resident", 0.25).unwrap_or(0.25);

    // ---- live loopback: sharded queues, 1 vs 4 QPs per node ----
    let mut live = Table::new(
        "Live loopback page-out burst (8 writers x 4096 pages, 3 nodes) — sharded IoEngine queues",
    )
    .headers(&["QPs per node", "throughput", "merged I/Os", "WQEs"]);
    let mut rates = Vec::new();
    for qps in [1usize, 4] {
        // measure twice, keep the better run (thread-scheduler noise)
        let (a, sa) = live_pageout_burst(qps, 8, 4096);
        let (b, sb) = live_pageout_burst(qps, 8, 4096);
        let (rate, s) = if a >= b { (a, sa) } else { (b, sb) };
        rates.push(rate);
        live.row(&[
            qps.to_string(),
            format!("{rate:.0} MB/s"),
            fmt::count(s.merged_ios),
            fmt::count(s.wqes),
        ]);
    }
    live.note(&format!(
        "4 sharded queues vs 1: {:.2}x — K channels per node move bytes in parallel (paper §6.1)",
        rates[1] / rates[0]
    ));
    live.print();

    // ---- simulated fabric: compact Fig 12 ----
    let cfg = FabricConfig::connectx3_fdr();
    let kv = || KvConfig {
        resident_frac: resident,
        ops: 40_000,
        ..KvConfig::small(voltdb(), Mix::Sys)
    };

    let mut t = Table::new(&format!(
        "Remote paging: VoltDB SYS, {:.0}% of working set in memory, 3 remote nodes (2x replication)",
        resident * 100.0
    ))
    .headers(&["stack", "app throughput", "p99 op latency", "RDMA I/Os", "bytes on wire"]);

    let mut base = 0.0;
    for stack in [
        StackConfig::rdmabox(&cfg),
        baselines::nbdx(&cfg, 128 << 10),
        baselines::nbdx(&cfg, 512 << 10),
    ] {
        let name = stack.name.clone();
        let (report, stats) = run_kv(&cfg, &stack, kv());
        if base == 0.0 {
            base = stats.throughput();
        }
        t.row(&[
            format!(
                "{name}{}",
                if stats.throughput() == base {
                    String::new()
                } else {
                    format!("  ({:.2}x slower)", base / stats.throughput())
                }
            ),
            fmt::ops(stats.throughput()),
            fmt::dur_ns(stats.op_lat.p99()),
            fmt::count(report.trace.wqes_total()),
            fmt::bytes(report.trace.bytes_wire),
        ]);
    }
    t.note("nbdX rounds every page fault to its fixed block size — the wire amplification is the gap");
    t.print();
}
