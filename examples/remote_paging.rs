//! Remote paging demo: VoltDB-style workload under a container memory
//! limit, paging against remote memory — RDMAbox vs nbdX (128K / 512K
//! block I/O) on the simulated fabric. A compact version of Fig 12.
//!
//! ```bash
//! cargo run --release --example remote_paging [-- --resident 0.25]
//! ```

use rdmabox::baselines;
use rdmabox::cli::{Args, Table};
use rdmabox::config::FabricConfig;
use rdmabox::coordinator::StackConfig;
use rdmabox::util::fmt;
use rdmabox::workloads::kv::{run_kv, voltdb, KvConfig, Mix};

fn main() {
    let args = Args::parse_env().unwrap_or_default();
    let resident = args.get_f64("resident", 0.25).unwrap_or(0.25);
    let cfg = FabricConfig::connectx3_fdr();

    let kv = || KvConfig {
        resident_frac: resident,
        ops: 40_000,
        ..KvConfig::small(voltdb(), Mix::Sys)
    };

    let mut t = Table::new(&format!(
        "Remote paging: VoltDB SYS, {:.0}% of working set in memory, 3 remote nodes (2x replication)",
        resident * 100.0
    ))
    .headers(&["stack", "app throughput", "p99 op latency", "RDMA I/Os", "bytes on wire"]);

    let mut base = 0.0;
    for stack in [
        StackConfig::rdmabox(&cfg),
        baselines::nbdx(&cfg, 128 << 10),
        baselines::nbdx(&cfg, 512 << 10),
    ] {
        let name = stack.name.clone();
        let (report, stats) = run_kv(&cfg, &stack, kv());
        if base == 0.0 {
            base = stats.throughput();
        }
        t.row(&[
            format!(
                "{name}{}",
                if stats.throughput() == base {
                    String::new()
                } else {
                    format!("  ({:.2}x slower)", base / stats.throughput())
                }
            ),
            fmt::ops(stats.throughput()),
            fmt::dur_ns(stats.op_lat.p99()),
            fmt::count(report.trace.wqes_total()),
            fmt::bytes(report.trace.bytes_wire),
        ]);
    }
    t.note("nbdX rounds every page fault to its fixed block size — the wire amplification is the gap");
    t.print();
}
