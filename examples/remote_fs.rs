//! Remote file system demo: IOzone-style sequential write/read over a file
//! striped across 10 server nodes — RDMAbox user-space library vs Octopus,
//! GlusterFS and Accelio design points. A compact version of Fig 14.
//!
//! ```bash
//! cargo run --release --example remote_fs [-- --record 1m --file 64m]
//! ```

use rdmabox::baselines;
use rdmabox::cli::{Args, Table};
use rdmabox::config::FabricConfig;
use rdmabox::coordinator::StackConfig;
use rdmabox::rfs::run_iozone;
use rdmabox::util::fmt;

fn main() {
    let args = Args::parse_env().unwrap_or_default();
    let record = args.get_u64("record", 1 << 20).unwrap_or(1 << 20);
    let file = args.get_u64("file", 64 << 20).unwrap_or(64 << 20);
    let cfg = FabricConfig::connectx3_fdr();
    let nodes = 10;

    let mut t = Table::new(&format!(
        "Remote FS: IOzone {}-record sweep over a {} file, 1 client / {} servers",
        fmt::bytes(record),
        fmt::bytes(file),
        nodes
    ))
    .headers(&["system", "write", "read"]);

    for (name, stack) in [
        ("RDMAbox", StackConfig::rdmabox_user(&cfg)),
        ("Octopus", baselines::octopus(&cfg)),
        ("GlusterFS", baselines::glusterfs(&cfg)),
        ("Accelio", baselines::accelio_fs(&cfg)),
    ] {
        let (w, r) = run_iozone(&cfg, &stack, nodes, record, file);
        t.row(&[
            name.to_string(),
            format!("{w:.2} GB/s"),
            format!("{r:.2} GB/s"),
        ]);
    }
    t.note("run `rdmabox fig 14` for the full record-size sweep with paper comparisons");
    t.print();
}
