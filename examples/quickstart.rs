//! Quickstart: the RDMAbox node-level abstraction on the live loopback
//! fabric — remote nodes are real threads owning real memory; writes and
//! reads go through the full `IoEngine` pipeline (sharded per-QP merge
//! queues → batch planner → admission window → poll-retire), the same
//! pipeline the discrete-event simulator drives for the figures.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use rdmabox::coordinator::EngineSpec;
use rdmabox::fabric::loopback::{LiveBox, LoopbackFabric};

fn main() {
    // 3 remote memory donors, 4 channels (QP shards) each, 64 MB donated
    let fabric = LoopbackFabric::start_sharded(3, 64 << 20, 4);
    let rbox = LiveBox::build(fabric, &EngineSpec::new(3).qps(4).window(Some(7 << 20)));
    println!(
        "cluster up: {} remote nodes x 4 QP shards per node",
        rbox.nodes()
    );

    // --- single-threaded write/read roundtrip ---
    let page = vec![0xAB_u8; 4096];
    rbox.write(0, 0, &page);
    let back = rbox.read(0, 0, 4096);
    assert_eq!(back, page);
    println!("roundtrip: wrote+read one page on node 0");

    // --- 8 threads writing 1024 pages, interleaved so neighbours come
    //     from different threads: load-aware batching merges the
    //     concurrent adjacent writes into multi-fragment WRs ---
    let mut handles = Vec::new();
    for t in 0..8u64 {
        let b = rbox.clone();
        handles.push(std::thread::spawn(move || {
            for i in 0..128u64 {
                let page_no = i * 8 + t; // thread-interleaved adjacency
                let data = vec![(page_no % 251) as u8; 4096];
                b.write(1, page_no * 4096, &data);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let s = rbox.stats();
    println!(
        "8 threads x 128 interleaved pages: {} bytes written via {} WQEs ({} posts, {} app I/Os merged)",
        s.bytes_written, s.wqes, s.posts, s.merged_ios
    );
    assert_eq!(s.bytes_written, 1024 * 4096 + 4096);

    // verify contents
    for page_no in 0..1024u64 {
        let b = rbox.read(1, page_no * 4096, 4096);
        assert_eq!(b[0], (page_no % 251) as u8);
    }
    println!("verified all 1024 pages — quickstart OK");
}
