#!/usr/bin/env python3
"""Gate a micro_core bench run against the checked-in baseline.

Usage:  check_bench.py BENCH_micro.json ci/bench_baseline.json

Fails (exit 1) when any bench named in the baseline regresses by more
than the tolerance (default 25%, override with BENCH_TOLERANCE=0.25):

  * throughput:  current ops_per_sec < baseline ops_per_sec * (1 - tol)
  * tail:        current p99_block_ns > baseline p99_block_ns * (1 + tol)

The shipped baseline holds deliberately conservative floors/ceilings
(an order of magnitude of headroom) so the gate is portable across CI
machines and catches only real regressions — an accidental O(n^2), a
debug-assert left in a hot loop, a pathological allocation. To tighten
it on pinned hardware, re-pin ci/bench_baseline.json from a recent
BENCH_micro artifact.

Benches present in the run but absent from the baseline are reported
informationally and do not gate (so adding a bench never breaks CI).
"""

import json
import os
import sys


def load(path):
    try:
        with open(path, "r", encoding="utf-8") as f:
            return json.load(f)
    except (OSError, ValueError) as e:
        print(f"check_bench: cannot read {path}: {e}", file=sys.stderr)
        sys.exit(2)


def main():
    if len(sys.argv) != 3:
        print(__doc__, file=sys.stderr)
        sys.exit(2)
    current = load(sys.argv[1])
    baseline = load(sys.argv[2])
    tol = float(os.environ.get("BENCH_TOLERANCE", "0.25"))

    cur_by_name = {b["name"]: b for b in current.get("benches", [])}
    failures = []
    print(f"bench gate: tolerance {tol:.0%}"
          f"{' (smoke run)' if current.get('smoke') else ''}")
    for base in baseline.get("benches", []):
        name = base["name"]
        cur = cur_by_name.pop(name, None)
        if cur is None:
            failures.append(f"{name}: present in baseline but missing from run")
            continue
        ops_floor = base["ops_per_sec"] * (1.0 - tol)
        verdicts = []
        if cur["ops_per_sec"] < ops_floor:
            verdicts.append(
                f"throughput {cur['ops_per_sec']:.0f} ops/s < floor "
                f"{ops_floor:.0f} (baseline {base['ops_per_sec']:.0f})"
            )
        # tail-gate only benches that report a real tail (single-shot
        # benches like des_end_to_end omit p99_block_ns)
        if "p99_block_ns" in base and "p99_block_ns" in cur:
            p99_ceil = base["p99_block_ns"] * (1.0 + tol)
            if cur["p99_block_ns"] > p99_ceil:
                verdicts.append(
                    f"p99 {cur['p99_block_ns']:.0f} ns > ceiling "
                    f"{p99_ceil:.0f} (baseline {base['p99_block_ns']:.0f})"
                )
        status = "FAIL" if verdicts else "ok"
        p99_str = (f"p99 {cur['p99_block_ns']:>10.1f} ns"
                   if "p99_block_ns" in cur else "p99          — ")
        print(f"  {name:28} {cur['ops_per_sec']:>14.0f} ops/s  "
              f"{p99_str}   {status}")
        for v in verdicts:
            failures.append(f"{name}: {v}")
    for name in cur_by_name:
        print(f"  {name:28} (no baseline entry — not gated)")

    if failures:
        print("\nbench gate FAILED (>{:.0%} regression):".format(tol),
              file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        sys.exit(1)
    print("bench gate passed")


if __name__ == "__main__":
    main()
