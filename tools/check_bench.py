#!/usr/bin/env python3
"""Gate a bench run (micro_core or macro_core) against its baseline.

Usage:  check_bench.py BENCH_micro.json ci/bench_baseline.json
        check_bench.py BENCH_macro.json ci/bench_macro_baseline.json

Fails (exit 1) when any bench named in the baseline regresses by more
than the tolerance (default 25%, override with BENCH_TOLERANCE=0.25):

  * throughput:  current ops_per_sec < baseline ops_per_sec * (1 - tol)
  * tail:        current p99_block_ns > baseline p99_block_ns * (1 + tol)
                 (micro benches: p99 of per-block wall-clock means);
                 same ceiling for p99_ns (macro benches: per-op
                 *virtual-time* p99, deterministic per code version)

Two exact (non-tolerance) gates ride along:

  * allocations: a baseline entry carrying "allocs_per_op" caps the
    bench's measured allocator events per op. A cap of 0 means the hot
    path must be allocation-free in steady state (the zero-allocation
    property of the engine's slab-ledger pipeline) — any nonzero reading
    is a regression, whatever the tolerance. Runs produced by an older
    bench binary that does not emit the field are tolerated (reported,
    not gated), so old artifacts keep checking cleanly.
  * ratios: a baseline entry carrying "min_ratio_vs": {"other": R}
    requires current ops_per_sec >= R * current[other].ops_per_sec —
    used for the in-tree slab-vs-hashmap ledger ablation and for the
    dynamic MR cache's hit-vs-miss pair (a resident-span lkey lookup
    must cost no more than a lazy registration + eviction, or the
    pinning-free cache is pure overhead), where the claim is relative,
    so both sides come from the same run and machine.
  * victim latency: a baseline entry carrying
    "victim_p99_max_ratio_vs": {"other": R} requires
    current victim_p99_ns <= R * current[other].victim_p99_ns. The
    benches emit victim_p99_ns in *virtual* time (deterministic drain
    rounds, no wall clock), so the ratio is exact — this is the
    multi-tenant QoS isolation claim (DRR must beat FIFO for the
    victim tenant), gated with no tolerance.

The shipped baseline holds deliberately conservative floors/ceilings
(an order of magnitude of headroom) so the gate is portable across CI
machines and catches only real regressions — an accidental O(n^2), a
debug-assert left in a hot loop, a pathological allocation. To tighten
it on pinned hardware, re-pin ci/bench_baseline.json from a recent
BENCH_micro artifact.

Benches present in the run but absent from the baseline are reported
informationally and do not gate (so adding a bench never breaks CI).
The reverse is typo-proofed: a baseline entry whose bench is missing
from the run fails the gate, and duplicate bench names in either file
fail immediately (a duplicate would silently shadow a gated entry).
"""

import json
import os
import sys


def load(path):
    try:
        with open(path, "r", encoding="utf-8") as f:
            return json.load(f)
    except (OSError, ValueError) as e:
        print(f"check_bench: cannot read {path}: {e}", file=sys.stderr)
        sys.exit(2)


def main():
    if len(sys.argv) != 3:
        print(__doc__, file=sys.stderr)
        sys.exit(2)
    current = load(sys.argv[1])
    baseline = load(sys.argv[2])
    tol = float(os.environ.get("BENCH_TOLERANCE", "0.25"))

    # duplicate names would silently shadow an entry in the dicts below
    for label, doc in (("run", current), ("baseline", baseline)):
        names = [b["name"] for b in doc.get("benches", [])]
        dupes = sorted({n for n in names if names.count(n) > 1})
        if dupes:
            print(f"check_bench: duplicate bench names in {label}: "
                  f"{', '.join(dupes)}", file=sys.stderr)
            sys.exit(1)

    cur_by_name = {b["name"]: b for b in current.get("benches", [])}
    all_cur = dict(cur_by_name)  # ratio checks may reference gated names
    failures = []
    print(f"bench gate: tolerance {tol:.0%}"
          f"{' (smoke run)' if current.get('smoke') else ''}")
    for base in baseline.get("benches", []):
        name = base["name"]
        cur = cur_by_name.pop(name, None)
        if cur is None:
            failures.append(f"{name}: present in baseline but missing from run")
            continue
        ops_floor = base["ops_per_sec"] * (1.0 - tol)
        verdicts = []
        if cur["ops_per_sec"] < ops_floor:
            verdicts.append(
                f"throughput {cur['ops_per_sec']:.0f} ops/s < floor "
                f"{ops_floor:.0f} (baseline {base['ops_per_sec']:.0f})"
            )
        # tail-gate only benches that report a real tail (single-shot
        # benches like des_end_to_end omit p99_block_ns)
        for tail_key in ("p99_block_ns", "p99_ns"):
            if tail_key in base and tail_key in cur:
                p99_ceil = base[tail_key] * (1.0 + tol)
                if cur[tail_key] > p99_ceil:
                    verdicts.append(
                        f"{tail_key} {cur[tail_key]:.0f} ns > ceiling "
                        f"{p99_ceil:.0f} (baseline {base[tail_key]:.0f})"
                    )
        # allocation gate: exact cap, no tolerance — missing-field
        # tolerant for artifacts from older bench binaries
        if "allocs_per_op" in base and "allocs_per_op" in cur:
            if cur["allocs_per_op"] > base["allocs_per_op"]:
                verdicts.append(
                    f"allocs/op {cur['allocs_per_op']:.4f} > cap "
                    f"{base['allocs_per_op']:.4f} (hot path allocates)"
                )
        # relative gate: both sides from the same run, so machine speed
        # cancels out
        for other, ratio in base.get("min_ratio_vs", {}).items():
            peer = all_cur.get(other)
            if peer is None:
                verdicts.append(f"ratio peer `{other}` missing from run")
            elif cur["ops_per_sec"] < ratio * peer["ops_per_sec"]:
                verdicts.append(
                    f"only {cur['ops_per_sec'] / max(peer['ops_per_sec'], 1e-9):.2f}x "
                    f"`{other}` ({cur['ops_per_sec']:.0f} vs "
                    f"{peer['ops_per_sec']:.0f} ops/s), need {ratio:.1f}x"
                )
        # victim-latency gate: virtual-time metric, deterministic per
        # binary, so the DRR-vs-FIFO ratio is exact (no tolerance)
        for other, ratio in base.get("victim_p99_max_ratio_vs", {}).items():
            peer = all_cur.get(other)
            if peer is None:
                verdicts.append(f"victim-p99 peer `{other}` missing from run")
            elif "victim_p99_ns" not in cur or "victim_p99_ns" not in peer:
                verdicts.append(
                    "victim_p99_ns missing from run (bench binary predates "
                    "the QoS fairness pair?)"
                )
            elif cur["victim_p99_ns"] > ratio * peer["victim_p99_ns"]:
                verdicts.append(
                    f"victim p99 {cur['victim_p99_ns']:.0f} ns > "
                    f"{ratio:.2f}x `{other}` ({peer['victim_p99_ns']:.0f} ns) "
                    f"— the QoS isolation claim regressed"
                )
        status = "FAIL" if verdicts else "ok"
        cur_tail = cur.get("p99_block_ns", cur.get("p99_ns"))
        p99_str = (f"p99 {cur_tail:>10.1f} ns"
                   if cur_tail is not None else "p99          — ")
        alloc_str = (f"  {cur['allocs_per_op']:>7.3f} allocs/op"
                     if "allocs_per_op" in cur else "")
        print(f"  {name:28} {cur['ops_per_sec']:>14.0f} ops/s  "
              f"{p99_str}{alloc_str}   {status}")
        for v in verdicts:
            failures.append(f"{name}: {v}")
    for name in cur_by_name:
        print(f"  {name:28} (no baseline entry — not gated)")

    if failures:
        print("\nbench gate FAILED (>{:.0%} regression):".format(tol),
              file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        sys.exit(1)
    print("bench gate passed")


if __name__ == "__main__":
    main()
