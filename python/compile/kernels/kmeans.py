"""L1 Pallas kernel: tiled nearest-centroid assignment for k-means.

TPU mapping: the distance matrix is computed per point-tile against the
full centroid set (K·D is small and stays VMEM-resident across grid
steps); squared distances use the ‖p‖²+‖c‖²−2p·c expansion so the inner
product runs on the MXU, and the argmin/min reduction happens in-kernel on
the VPU so the [N, K] distance matrix never hits HBM.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_N = 256


def _kernel(p_ref, c_ref, assign_ref, dmin_ref):
    p = p_ref[...]  # [TILE_N, D]
    c = c_ref[...]  # [K, D]
    p2 = jnp.sum(p * p, axis=1, keepdims=True)
    c2 = jnp.sum(c * c, axis=1)
    cross = jnp.dot(p, c.T, preferred_element_type=jnp.float32)
    d2 = p2 + c2[None, :] - 2.0 * cross
    assign_ref[...] = jnp.argmin(d2, axis=1).astype(jnp.int32)
    dmin_ref[...] = jnp.min(d2, axis=1)


@functools.partial(jax.jit, static_argnames=())
def kmeans_assign(points, centroids):
    """points: [N, D] f32, centroids: [K, D] f32 ->
    (assignments [N] i32, min squared distances [N] f32)."""
    n, d = points.shape
    k, _ = centroids.shape
    pad = (-n) % TILE_N
    if pad:
        points = jnp.pad(points, ((0, pad), (0, 0)))
    np_ = points.shape[0]
    assign, dmin = pl.pallas_call(
        _kernel,
        grid=(np_ // TILE_N,),
        in_specs=[
            pl.BlockSpec((TILE_N, d), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((TILE_N,), lambda i: (i,)),
            pl.BlockSpec((TILE_N,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((np_,), jnp.int32),
            jax.ShapeDtypeStruct((np_,), jnp.float32),
        ],
        interpret=True,
    )(points, centroids)
    return assign[:n], dmin[:n]


def vmem_bytes(d: int, k: int) -> int:
    """Static VMEM footprint estimate per grid step."""
    p_tile = TILE_N * d * 4
    c = k * d * 4
    d2 = TILE_N * k * 4
    outs = TILE_N * 8
    return p_tile + c + d2 + outs
