"""L1 Pallas kernel: fused matmul + sigmoid for logistic regression.

TPU mapping (DESIGN.md §Hardware-Adaptation): the minibatch matmul is the
MXU-bound hot spot; we tile the batch dimension so each grid step keeps an
[TB, F] X-tile plus the full weight vector resident in VMEM
(TB=128, F≤2048 → ≈1 MB — comfortably under the ~16 MB VMEM budget), and
fuse the sigmoid into the same kernel so activations never round-trip to
HBM. interpret=True everywhere: the CPU PJRT client cannot execute Mosaic
custom-calls (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Batch tile: one MXU-friendly stripe of rows per grid step.
TILE_B = 128


def _kernel(x_ref, w_ref, o_ref):
    x = x_ref[...]  # [TILE_B, F]
    w = w_ref[...]  # [F]
    z = jnp.dot(x, w, preferred_element_type=jnp.float32)
    o_ref[...] = 1.0 / (1.0 + jnp.exp(-z))


@functools.partial(jax.jit, static_argnames=())
def logreg_forward(x, w):
    """Probabilities sigmoid(x @ w). x: [B, F] f32 (B % TILE_B == 0 after
    padding), w: [F] f32 -> [B] f32."""
    b, f = x.shape
    pad = (-b) % TILE_B
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    bp = x.shape[0]
    out = pl.pallas_call(
        _kernel,
        grid=(bp // TILE_B,),
        in_specs=[
            pl.BlockSpec((TILE_B, f), lambda i: (i, 0)),
            pl.BlockSpec((f,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((TILE_B,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((bp,), jnp.float32),
        interpret=True,
    )(x, w)
    return out[:b]


def vmem_bytes(f: int) -> int:
    """Static VMEM footprint estimate per grid step (DESIGN.md §8)."""
    x_tile = TILE_B * f * 4
    w = f * 4
    out = TILE_B * 4
    return x_tile + w + out
