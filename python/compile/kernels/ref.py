"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness).

Every Pallas kernel in this package has an exact (up to float tolerance)
counterpart here; pytest sweeps shapes/dtypes with hypothesis and asserts
allclose. The references are also what the L2 model would compute without
the custom kernels, so they double as the performance baseline.
"""

import jax.numpy as jnp


def logreg_forward(x, w):
    """Fused matmul + sigmoid: probabilities for a logistic-regression
    minibatch. x: [B, F] float32, w: [F] float32 -> [B] float32."""
    return 1.0 / (1.0 + jnp.exp(-(x @ w)))


def kmeans_assign(points, centroids):
    """Nearest-centroid assignment. points: [N, D], centroids: [K, D]
    -> (assignments [N] int32, min squared distances [N] float32)."""
    p2 = jnp.sum(points * points, axis=1, keepdims=True)  # [N,1]
    c2 = jnp.sum(centroids * centroids, axis=1)  # [K]
    cross = points @ centroids.T  # [N,K]
    d2 = p2 + c2[None, :] - 2.0 * cross
    assign = jnp.argmin(d2, axis=1).astype(jnp.int32)
    dmin = jnp.min(d2, axis=1)
    return assign, dmin


def pagerank_step(m, r, damping):
    """One damped power-iteration step. m: [N, N] column-stochastic,
    r: [N] -> [N]."""
    n = r.shape[0]
    return damping * (m @ r) + (1.0 - damping) / n
