"""L1 Pallas kernel: damped power-iteration step for TextRank/PageRank.

TPU mapping: the rank vector stays VMEM-resident while the transition
matrix streams through HBM→VMEM in [TILE_R, N] row stripes (the matrix is
the big operand — this is the bandwidth-bound kernel of the three, with
arithmetic intensity ≈ 0.25 FLOP/byte; DESIGN.md §8). The damping update
is fused so the intermediate m@r never materializes in HBM.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_R = 128


def _kernel(m_ref, r_ref, damping_ref, o_ref):
    m = m_ref[...]  # [TILE_R, N]
    r = r_ref[...]  # [N]
    d = damping_ref[0]
    n = r.shape[0]
    mv = jnp.dot(m, r, preferred_element_type=jnp.float32)
    o_ref[...] = d * mv + (1.0 - d) / n


@functools.partial(jax.jit, static_argnames=())
def pagerank_step(m, r, damping):
    """m: [N, N] f32 column-stochastic, r: [N] f32, damping: scalar f32
    -> [N] f32. N must be a multiple of TILE_R (model pads)."""
    n = r.shape[0]
    assert n % TILE_R == 0, f"N={n} must be a multiple of {TILE_R}"
    dvec = jnp.reshape(damping.astype(jnp.float32), (1,))
    return pl.pallas_call(
        _kernel,
        grid=(n // TILE_R,),
        in_specs=[
            pl.BlockSpec((TILE_R, n), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((TILE_R,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(m, r, dvec)


def vmem_bytes(n: int) -> int:
    """Static VMEM footprint estimate per grid step."""
    m_tile = TILE_R * n * 4
    r = n * 4
    out = TILE_R * 4
    return m_tile + r + out
