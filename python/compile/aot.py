"""AOT lowering: L2 models (with their L1 Pallas kernels) → HLO *text*.

HLO text — NOT `.serialize()` — is the interchange format: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids which the xla_extension 0.5.1
bundled with the `xla` 0.1.6 crate rejects (`proto.id() <= INT_MAX`); the
text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md and gen_hlo.py.

Run once by `make artifacts`; Python never appears on the request path.
Emits, per model: `<name>.hlo.txt` plus a `manifest.txt` describing the
argument/result shapes the Rust runtime should feed it.
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(fn, example_args) -> str:
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec_str(s) -> str:
    dims = "x".join(str(d) for d in s.shape) if s.shape else "scalar"
    return f"{s.dtype}[{dims}]"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="artifacts dir")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest_lines = []
    for name, fn, example_args in model.aot_specs():
        text = to_hlo_text(fn, example_args)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        arg_desc = ",".join(spec_str(a) for a in example_args)
        manifest_lines.append(f"{name} args={arg_desc}")
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote {os.path.join(args.out, 'manifest.txt')}")


if __name__ == "__main__":
    main()
