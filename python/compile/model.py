"""L2 JAX models: the ML-workload training steps (paper §7.1.2), each
calling its L1 Pallas kernel. These are the computations the Rust
coordinator executes through PJRT after `aot.py` lowers them to HLO text.

All steps are pure functions (state, batch) -> (new_state, metric), so the
Rust side can iterate them with no Python anywhere on the path.
"""

import jax
import jax.numpy as jnp

from .kernels import kmeans as kmeans_kernel
from .kernels import logreg as logreg_kernel
from .kernels import pagerank as pagerank_kernel

# Fixed AOT shapes (the Rust runtime loads one executable per variant).
LOGREG_BATCH = 256
LOGREG_FEATURES = 512
KMEANS_POINTS = 1024
KMEANS_DIM = 32
KMEANS_K = 16
PAGERANK_N = 512
PAGERANK_DAMPING = 0.85


def logreg_step(w, x, y, lr):
    """One SGD step of L2-regularized logistic regression.

    w: [F], x: [B, F], y: [B] in {0,1}, lr scalar ->
    (w', mean binary cross-entropy loss).
    """
    p = logreg_kernel.logreg_forward(x, w)  # Pallas: fused matmul+sigmoid
    eps = 1e-7
    p = jnp.clip(p, eps, 1.0 - eps)
    loss = -jnp.mean(y * jnp.log(p) + (1.0 - y) * jnp.log(1.0 - p))
    grad = x.T @ (p - y) / x.shape[0] + 1e-4 * w
    return w - lr * grad, loss


def kmeans_step(centroids, points):
    """One Lloyd iteration: assign (Pallas) then recenter.

    centroids: [K, D], points: [N, D] -> (centroids', inertia).
    Empty clusters keep their previous centroid.
    """
    assign, dmin = kmeans_kernel.kmeans_assign(points, centroids)
    k = centroids.shape[0]
    one_hot = jax.nn.one_hot(assign, k, dtype=points.dtype)  # [N, K]
    counts = one_hot.sum(axis=0)  # [K]
    sums = one_hot.T @ points  # [K, D]
    new = jnp.where(
        counts[:, None] > 0, sums / jnp.maximum(counts[:, None], 1.0), centroids
    )
    return new, jnp.sum(dmin)


def pagerank_step(r, m):
    """One damped power-iteration step (Pallas SpMV) + L1 delta.

    r: [N], m: [N, N] column-stochastic -> (r', ||r'-r||_1).
    """
    r2 = pagerank_kernel.pagerank_step(m, r, jnp.float32(PAGERANK_DAMPING))
    return r2, jnp.sum(jnp.abs(r2 - r))


def aot_specs():
    """(name, fn, example_args) for every executable `aot.py` emits."""
    f32 = jnp.float32
    return [
        (
            "logreg_step",
            logreg_step,
            (
                jax.ShapeDtypeStruct((LOGREG_FEATURES,), f32),
                jax.ShapeDtypeStruct((LOGREG_BATCH, LOGREG_FEATURES), f32),
                jax.ShapeDtypeStruct((LOGREG_BATCH,), f32),
                jax.ShapeDtypeStruct((), f32),
            ),
        ),
        (
            "kmeans_step",
            kmeans_step,
            (
                jax.ShapeDtypeStruct((KMEANS_K, KMEANS_DIM), f32),
                jax.ShapeDtypeStruct((KMEANS_POINTS, KMEANS_DIM), f32),
            ),
        ),
        (
            "pagerank_step",
            pagerank_step,
            (
                jax.ShapeDtypeStruct((PAGERANK_N,), f32),
                jax.ShapeDtypeStruct((PAGERANK_N, PAGERANK_N), f32),
            ),
        ),
    ]
