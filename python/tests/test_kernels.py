"""L1 correctness: every Pallas kernel against its pure-jnp oracle.

Hypothesis sweeps shapes (including non-multiples of the tile sizes, which
exercise the padding paths) and value ranges; assert_allclose is the core
signal that the interpret-mode kernels compute exactly what the reference
does.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import kmeans, logreg, pagerank, ref

RNG = np.random.default_rng(0)


def rand(*shape, scale=1.0):
    return (RNG.standard_normal(shape) * scale).astype(np.float32)


# ---------------------------------------------------------------- logreg


class TestLogreg:
    def test_matches_ref_basic(self):
        x, w = rand(256, 64), rand(64)
        got = logreg.logreg_forward(jnp.asarray(x), jnp.asarray(w))
        want = ref.logreg_forward(jnp.asarray(x), jnp.asarray(w))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    @settings(max_examples=20, deadline=None)
    @given(
        b=st.integers(1, 300),
        f=st.integers(1, 96),
        scale=st.sampled_from([0.1, 1.0, 4.0]),
    )
    def test_matches_ref_swept(self, b, f, scale):
        x, w = rand(b, f, scale=scale), rand(f, scale=scale)
        got = logreg.logreg_forward(jnp.asarray(x), jnp.asarray(w))
        want = ref.logreg_forward(jnp.asarray(x), jnp.asarray(w))
        assert got.shape == (b,)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_outputs_are_probabilities(self):
        x, w = rand(128, 32, scale=5.0), rand(32, scale=5.0)
        p = np.asarray(logreg.logreg_forward(jnp.asarray(x), jnp.asarray(w)))
        assert (p >= 0).all() and (p <= 1).all()

    def test_vmem_estimate_reasonable(self):
        # tile footprint must fit a 16 MB VMEM budget for the AOT shapes
        assert logreg.vmem_bytes(512) < 16 * 2**20


# ---------------------------------------------------------------- kmeans


class TestKmeans:
    def test_matches_ref_basic(self):
        p, c = rand(512, 16), rand(8, 16)
        ga, gd = kmeans.kmeans_assign(jnp.asarray(p), jnp.asarray(c))
        wa, wd = ref.kmeans_assign(jnp.asarray(p), jnp.asarray(c))
        np.testing.assert_array_equal(ga, wa)
        np.testing.assert_allclose(gd, wd, rtol=1e-4, atol=1e-4)

    @settings(max_examples=20, deadline=None)
    @given(
        n=st.integers(1, 600),
        d=st.integers(1, 48),
        k=st.integers(1, 12),
    )
    def test_matches_ref_swept(self, n, d, k):
        p, c = rand(n, d), rand(k, d)
        ga, gd = kmeans.kmeans_assign(jnp.asarray(p), jnp.asarray(c))
        wa, wd = ref.kmeans_assign(jnp.asarray(p), jnp.asarray(c))
        assert ga.shape == (n,) and gd.shape == (n,)
        # ties can break differently only if two centroids are equidistant
        # (measure-zero with gaussian data); require exact agreement
        np.testing.assert_array_equal(ga, wa)
        np.testing.assert_allclose(gd, wd, rtol=1e-3, atol=1e-3)

    def test_assignment_is_argmin(self):
        p, c = rand(64, 8), rand(4, 8)
        ga, _ = kmeans.kmeans_assign(jnp.asarray(p), jnp.asarray(c))
        d2 = ((p[:, None, :] - c[None, :, :]) ** 2).sum(-1)
        np.testing.assert_array_equal(np.asarray(ga), d2.argmin(1))

    def test_vmem_estimate_reasonable(self):
        assert kmeans.vmem_bytes(32, 16) < 16 * 2**20


# -------------------------------------------------------------- pagerank


class TestPagerank:
    def _stochastic(self, n):
        m = np.abs(RNG.standard_normal((n, n))).astype(np.float32) + 0.01
        return m / m.sum(axis=0, keepdims=True)

    def test_matches_ref_basic(self):
        n = 256
        m = self._stochastic(n)
        r = np.full(n, 1.0 / n, dtype=np.float32)
        got = pagerank.pagerank_step(jnp.asarray(m), jnp.asarray(r), jnp.float32(0.85))
        want = ref.pagerank_step(jnp.asarray(m), jnp.asarray(r), 0.85)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    @settings(max_examples=10, deadline=None)
    @given(tiles=st.integers(1, 4), damping=st.sampled_from([0.5, 0.85, 0.99]))
    def test_matches_ref_swept(self, tiles, damping):
        n = tiles * pagerank.TILE_R
        m = self._stochastic(n)
        r = np.abs(rand(n)) + 0.01
        r = r / r.sum()
        got = pagerank.pagerank_step(
            jnp.asarray(m), jnp.asarray(r), jnp.float32(damping)
        )
        want = ref.pagerank_step(jnp.asarray(m), jnp.asarray(r), damping)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-6)

    def test_preserves_probability_mass(self):
        n = 128
        m = self._stochastic(n)
        r = np.full(n, 1.0 / n, dtype=np.float32)
        r2 = pagerank.pagerank_step(jnp.asarray(m), jnp.asarray(r), jnp.float32(0.85))
        assert abs(float(np.asarray(r2).sum()) - 1.0) < 1e-4

    def test_rejects_unaligned_n(self):
        n = pagerank.TILE_R + 1
        m = self._stochastic(n)
        r = np.full(n, 1.0 / n, dtype=np.float32)
        with pytest.raises(AssertionError):
            pagerank.pagerank_step(jnp.asarray(m), jnp.asarray(r), jnp.float32(0.85))
