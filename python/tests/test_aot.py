"""AOT path: lowering to HLO text works, the text is parseable-looking HLO
(ENTRY present, tuple return), and the manifest matches the specs."""

import os
import subprocess
import sys

from compile import aot, model


class TestHloText:
    def test_every_model_lowers_to_hlo_text(self):
        for name, fn, args in model.aot_specs():
            text = aot.to_hlo_text(fn, args)
            assert "ENTRY" in text, name
            assert "HloModule" in text, name
            # return_tuple=True -> root is a tuple
            assert "tuple" in text, name

    def test_hlo_is_deterministic(self):
        name, fn, args = model.aot_specs()[0]
        a = aot.to_hlo_text(fn, args)
        b = aot.to_hlo_text(fn, args)
        assert a == b

    def test_spec_str_format(self):
        import jax

        s = jax.ShapeDtypeStruct((256, 512), "float32")
        assert aot.spec_str(s) == "float32[256x512]"
        scalar = jax.ShapeDtypeStruct((), "float32")
        assert aot.spec_str(scalar) == "float32[scalar]"


class TestAotMain(object):
    def test_main_writes_artifacts(self, tmp_path):
        out = str(tmp_path)
        r = subprocess.run(
            [sys.executable, "-m", "compile.aot", "--out", out],
            cwd=os.path.join(os.path.dirname(__file__), ".."),
            capture_output=True,
            text=True,
            timeout=600,
        )
        assert r.returncode == 0, r.stderr
        expected = ["logreg_step.hlo.txt", "kmeans_step.hlo.txt", "pagerank_step.hlo.txt", "manifest.txt"]
        for f in expected:
            p = os.path.join(out, f)
            assert os.path.exists(p), f
            assert os.path.getsize(p) > 0, f
        manifest = open(os.path.join(out, "manifest.txt")).read()
        assert "logreg_step args=" in manifest
        assert "float32[256x512]" in manifest
