"""L2 model sanity: the training steps learn / converge, and the AOT specs
cover every model with the advertised shapes."""

import numpy as np

import jax.numpy as jnp

from compile import model

RNG = np.random.default_rng(1)


class TestLogregStep:
    def _data(self, b, f):
        w_true = RNG.standard_normal(f).astype(np.float32)
        x = RNG.standard_normal((b, f)).astype(np.float32)
        y = (x @ w_true > 0).astype(np.float32)
        return x, y

    def test_loss_decreases(self):
        f = model.LOGREG_FEATURES
        b = model.LOGREG_BATCH
        x, y = self._data(b, f)
        w = jnp.zeros(f, dtype=jnp.float32)
        losses = []
        for _ in range(30):
            w, loss = model.logreg_step(w, jnp.asarray(x), jnp.asarray(y), jnp.float32(0.5))
            losses.append(float(loss))
        assert losses[-1] < losses[0] * 0.7, losses[:3] + losses[-3:]

    def test_shapes_stable(self):
        f, b = 64, 128
        x, y = self._data(b, f)
        w = jnp.zeros(f, dtype=jnp.float32)
        w2, loss = model.logreg_step(w, jnp.asarray(x), jnp.asarray(y), jnp.float32(0.1))
        assert w2.shape == (f,)
        assert loss.shape == ()


class TestKmeansStep:
    def test_inertia_decreases(self):
        pts = np.concatenate(
            [
                RNG.standard_normal((256, 8)).astype(np.float32) + 5.0,
                RNG.standard_normal((256, 8)).astype(np.float32) - 5.0,
            ]
        )
        c = RNG.standard_normal((4, 8)).astype(np.float32)
        inertias = []
        c = jnp.asarray(c)
        for _ in range(10):
            c, inertia = model.kmeans_step(c, jnp.asarray(pts))
            inertias.append(float(inertia))
        assert inertias[-1] <= inertias[0]
        # Lloyd's algorithm is monotone non-increasing
        for a, b in zip(inertias, inertias[1:]):
            assert b <= a + 1e-3, inertias

    def test_empty_cluster_keeps_centroid(self):
        pts = np.zeros((16, 4), dtype=np.float32)
        c = np.stack(
            [np.zeros(4, dtype=np.float32), np.full(4, 100.0, dtype=np.float32)]
        )
        c2, _ = model.kmeans_step(jnp.asarray(c), jnp.asarray(pts))
        np.testing.assert_allclose(np.asarray(c2)[1], c[1])


class TestPagerankStep:
    def test_converges_to_fixed_point(self):
        n = model.PAGERANK_N
        m = np.abs(RNG.standard_normal((n, n))).astype(np.float32) + 0.01
        m = m / m.sum(axis=0, keepdims=True)
        r = jnp.full((n,), 1.0 / n, dtype=jnp.float32)
        deltas = []
        for _ in range(25):
            r, delta = model.pagerank_step(r, jnp.asarray(m))
            deltas.append(float(delta))
        assert deltas[-1] < deltas[0] * 0.01, (deltas[0], deltas[-1])


class TestAotSpecs:
    def test_specs_cover_all_models(self):
        names = [name for name, _, _ in model.aot_specs()]
        assert names == ["logreg_step", "kmeans_step", "pagerank_step"]

    def test_specs_are_traceable(self):
        import jax

        for name, fn, args in model.aot_specs():
            lowered = jax.jit(fn).lower(*args)
            assert lowered is not None, name
