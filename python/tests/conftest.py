"""Make the `compile` package importable no matter where pytest is
invoked from (repo root, `python/`, or elsewhere): this conftest sits
next to the test modules, so pytest always loads it."""

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
